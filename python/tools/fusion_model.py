"""Deterministic twin of rust/src/sched + rust/src/shard + rust/src/fault
+ rust/src/trace + rust/src/metrics + rust/src/hybrid for the
EXPERIMENTS.md tables (E-FUSE-1, E-SHARD-1, E-FAULT-1, E-TRACE-1,
E-OBS-1, E-HYBRID-1 and E-HETERO-1).

The offline container has no Rust toolchain, so this script mirrors the
exact counting semantics of the fused scheduler (rust/src/sched), the
shard device group (rust/src/shard: per-device round-robin fusion,
lock-step group steps with a barrier, epoch-boundary rebalancing,
injected device faults with evacuation and an elastically shrinking
barrier), and the cost models (rust/src/simt GpuModel + DeviceGroup)
for apps whose epoch schedules are RNG-independent: fib, mergesort
(structure does not depend on the data values), nqueens, and BFS on the
deterministic 4-neighbor grid. Every quantity printed here is a *model*
quantity (epoch counts, live lanes, bucket-tiled launches, modeled
microseconds) — `cargo bench --bench bench_fusion`, `--bench
bench_shard`, `--bench bench_serve` and `--bench bench_trace` compute
the same numbers from the real machines. The E-FAULT-1 twin also
snapshots the repo-root BENCH_serve.json, the E-TRACE-1 twin
(critical-path window twin of rust/src/trace) snapshots
BENCH_trace.json, the E-OBS-1 twin mirrors the rust/src/metrics
registry (log2-bucket latency histograms, SLO counters, utilization
gauges) over the same serve feed, and the E-HYBRID-1 twin mirrors the
rust/src/hybrid crossover router (CpuModel, greedy peel + bulk
fallback + hysteresis) and snapshots BENCH_hybrid.json — the same
numbers `cargo bench --bench bench_hybrid` computes from the real
engines. The E-HETERO-1 twin mirrors the heterogeneous group planner
(per-member speed multipliers, speed-normalized LPT re-packing, and
one-epoch slice steals under the strict never-worse envelope from
rust/src/shard/balance.rs) and snapshots BENCH_hetero.json — the twin
of `cargo bench --bench bench_hetero`.

Run:  python tools/fusion_model.py
"""

import json
import math
import os
import time

# ------------------------------- TVM machine (mirrors tvm::Interp)


class Ctx:
    def __init__(self, res, heap, const, next_child):
        self.res = res
        self.heap = heap
        self.const = const
        self.forks = []
        self.join = None
        self.emit = None
        self.scat_min = []
        self.next_child = next_child

    def fork(self, tid, args):
        slot = self.next_child
        self.next_child += 1
        self.forks.append((tid, args))
        return slot

    def do_join(self, tid, args):
        self.join = (tid, args)

    def do_emit(self, v):
        self.emit = v

    def scatter_min(self, idx, val):
        self.scat_min.append((idx, val))


class Machine:
    """The reference interpreter's counters (tvm::Interp twin)."""

    def __init__(self, run_task, t_types, capacity, init_args,
                 heap=None, const=None):
        self.run_task = run_task
        self.T = t_types
        self.code = [0] * capacity
        self.args = [None] * capacity
        self.res = [0] * capacity
        self.heap = heap or []
        self.const = const or []
        self.code[0] = 1  # epoch 0, tid 1
        self.args[0] = list(init_args)
        self.next_free = 1
        self.join_stack = [0]
        self.nd_stack = [(0, 1)]
        self.epochs = 0
        self.work = 0

    def front(self):
        if not self.join_stack:
            return None
        return (self.join_stack[-1],) + self.nd_stack[-1]

    def live_in(self, cen, lo, hi):
        n = 0
        for s in range(lo, hi):
            c = self.code[s]
            if c > 0 and (c - 1) // self.T == cen:
                n += 1
        return n

    def step(self):
        if not self.join_stack:
            return False
        cen = self.join_stack.pop()
        lo, hi = self.nd_stack.pop()
        old_nf = self.next_free
        join_scheduled = False
        scat = []
        for slot in range(lo, hi):
            c = self.code[slot]
            if c <= 0 or (c - 1) // self.T != cen:
                continue
            tid = c - ((c - 1) // self.T) * self.T
            self.work += 1
            ctx = Ctx(self.res, self.heap, self.const, self.next_free)
            self.run_task(tid, self.args[slot], ctx)
            for ftid, fargs in ctx.forks:
                s = self.next_free
                self.code[s] = (cen + 1) * self.T + ftid
                self.args[s] = fargs
                self.next_free += 1
            if ctx.join is not None:
                jtid, jargs = ctx.join
                self.code[slot] = cen * self.T + jtid
                self.args[slot] = jargs
                join_scheduled = True
            else:
                self.code[slot] = 0
            if ctx.emit is not None:
                self.res[slot] = ctx.emit
            scat.extend(ctx.scat_min)
        self.epochs += 1
        for idx, val in scat:
            self.heap[idx] = min(self.heap[idx], val)
        # tms_update (tvm::tms_update twin)
        if join_scheduled:
            self.join_stack.append(cen)
            self.nd_stack.append((lo, hi))
        if self.next_free > old_nf:
            self.join_stack.append(cen + 1)
            self.nd_stack.append((old_nf, self.next_free))
        if not join_scheduled and self.next_free == old_nf \
                and hi == self.next_free:
            self.next_free = lo
        return True


# ------------------------------- apps (sched::job builder twins)


def fib_cap(n):
    a, b = 0, 1
    for _ in range(n + 1):
        a, b = b, a + b
    return max(2 * a, 64) + 64


def make_fib(n):
    def run(tid, args, ctx):
        if tid == 1:
            m = args[0]
            if m < 2:
                ctx.do_emit(m)
            else:
                c0 = ctx.fork(1, [m - 1])
                c1 = ctx.fork(1, [m - 2])
                ctx.do_join(2, [c0, c1])
        else:
            ctx.do_emit(ctx.res[args[0]] + ctx.res[args[1]])
    return Machine(run, 2, fib_cap(n), [n])


def make_nqueens(n):
    def run(tid, args, ctx):
        if tid == 1:
            row, cols, d1, d2 = args
            if row >= n:
                ctx.do_emit(1)
                return
            attacked = cols | d1 | d2
            first, count = -1, 0
            for c in range(n):
                bit = 1 << c
                if attacked & bit == 0:
                    s = ctx.fork(1, [row + 1, cols | bit,
                                     ((d1 | bit) << 1) & 0xFFF,
                                     (d2 | bit) >> 1])
                    if first < 0:
                        first = s
                    count += 1
            if count > 0:
                ctx.do_join(2, [first, count])
            else:
                ctx.do_emit(0)
        else:
            first, count = args
            ctx.do_emit(sum(ctx.res[first + k] for k in range(count)))
    return Machine(run, 2, 1 << 16 if n <= 8 else 1 << 21, [0, 0, 0, 0])


G_LEAF = 4


def make_msort(n):
    n2 = 1
    while n2 < max(n, G_LEAF):
        n2 *= 2

    def run(tid, args, ctx):
        if tid == 1:
            lo, hi = args
            if hi - lo > G_LEAF:
                mid = (lo + hi) // 2
                ctx.fork(1, [lo, mid])
                ctx.fork(1, [mid, hi])
                ctx.do_join(2, [lo, mid, hi])
            # leaf sort: scatters only; no effect on the schedule
        # merge task: full-range serial merge, no forks
    return Machine(run, 2, max(16 * n2, 64), [0, n2])


def grid_csr(side):
    """gen::grid2d adjacency (weights ignored: BFS is unweighted)."""
    adj = [[] for _ in range(side * side)]
    vid = lambda r, c: r * side + c
    for r in range(side):
        for c in range(side):
            if c + 1 < side:
                adj[vid(r, c)].append(vid(r, c + 1))
                adj[vid(r, c + 1)].append(vid(r, c))
            if r + 1 < side:
                adj[vid(r, c)].append(vid(r + 1, c))
                adj[vid(r + 1, c)].append(vid(r, c))
    row_ptr, col = [0], []
    for u in range(len(adj)):
        col.extend(adj[u])
        row_ptr.append(len(col))
    return row_ptr, col


def make_bfs(side):
    row_ptr, col = grid_csr(side)
    nv = side * side
    ne = len(col)
    INF = 1 << 30
    heap = [INF] * nv
    heap[0] = 0

    def run(tid, args, ctx):
        if tid == 1:  # visit
            u, d = args
            if ctx.heap[u] != d:
                return
            rp0, rp1 = row_ptr[u], row_ptr[u + 1]
            if rp1 > rp0:
                ctx.fork(2, [u, rp0, rp1, d])
        else:  # expand
            u, lo, hi, d = args
            if ctx.heap[u] != d:
                return
            if hi - lo > 2:
                mid = (lo + hi) // 2
                ctx.fork(2, [u, lo, mid, d])
                ctx.fork(2, [u, mid, hi, d])
            else:
                for e in range(lo, hi):
                    v = col[e]
                    nd = d + 1
                    if nd < ctx.heap[v]:
                        ctx.scatter_min(v, nd)
                        ctx.fork(1, [v, nd])
    return Machine(run, 2, 64 * (nv + 4 * ne) + 64, [0, 0], heap=heap)


def build(token):
    app, _, arg = token.partition(":")
    n = int(arg)
    return {"fib": make_fib, "mergesort": make_msort,
            "nqueens": make_nqueens, "bfs": make_bfs}[app](n)


# ------------------------------- fuser + policy + model twins

BUCKETS = [256, 1024, 4096]
CAPACITY, SLICE_CAP = 4096, 1024
CUS, SIMD, TASK_CYCLES, GHZ, LAUNCH_US, DIVERGENCE = 8, 64, 400.0, 0.72, 10.0, 2.0


def launches_for(length):
    if length == 0:
        return 0
    n = 0
    while length > 0:
        w = next((b for b in BUCKETS if b >= length), BUCKETS[-1])
        length = max(0, length - w)
        n += 1
    return n


def epoch_us(live, launches):
    waves = max(math.ceil(live / (CUS * SIMD)), 1.0)
    return waves * TASK_CYCLES * DIVERGENCE / (GHZ * 1e3) + launches * LAUNCH_US


def fused_epoch_us(live_per_job):
    total = sum(live_per_job)
    waves = max(math.ceil(total / (CUS * SIMD)), 1.0)
    jobs_live = sum(1 for l in live_per_job if l > 0)
    boundary = min(max(jobs_live - 1, 0), waves - 1)
    coherent = waves - boundary
    wave_us = TASK_CYCLES / (GHZ * 1e3)
    split = max(math.log2(SIMD), DIVERGENCE)
    return (coherent * DIVERGENCE + boundary * split) * wave_us + LAUNCH_US


class RoundRobin:
    def __init__(self):
        self.cursor = 0

    def select(self, fronts):
        if not fronts:
            return []
        n = len(fronts)
        start = self.cursor % n
        budget = CAPACITY
        out = []
        for k in range(n):
            idx, length = fronts[(start + k) % n]
            charge = max(min(length, SLICE_CAP), 1)
            if not out or charge <= budget:
                out.append(idx)
                budget = max(0, budget - charge)
        self.cursor = (start + 1) % n
        return out

    def retire(self, pos):
        if pos < self.cursor:
            self.cursor -= 1


def run_fused(tokens):
    """One fused scheduler = a 1-device shard group with no barrier;
    expressed through ShardDevice so the E-FUSE and E-SHARD twins share
    one fused-step implementation and cannot drift."""
    dev = ShardDevice()
    for t in tokens:
        dev.admit(build(t))
    steps = 0
    fused_us = 0.0
    while dev.has_work():
        live_per_job, step_launches = dev.step()
        steps += 1
        fused_us += fused_epoch_us(live_per_job) \
            + (step_launches - 1) * LAUNCH_US
    return dict(steps=steps, launches=dev.launches, work=dev.work,
                us=fused_us)


def run_solo(tokens):
    launches = syncs = work = 0
    us = 0.0
    for t in tokens:
        m = build(t)
        while m.front() is not None:
            cen, lo, hi = m.front()
            live = m.live_in(cen, lo, hi)
            l = launches_for(hi - lo)
            launches += l
            syncs += 1
            us += epoch_us(live, l)
            m.step()
        work += m.work
    return dict(launches=launches, syncs=syncs, work=work, us=us)


# ------------------------------- shard twins (rust/src/shard)

BARRIER_HOP_US = 2.0
SKEW_THRESHOLD, COOLDOWN = 1.5, 2
MAX_ACTIVE = 16  # SchedConfig::default().max_active


def barrier_us(devices):
    """simt::DeviceGroup::barrier_us twin (log2-depth signal tree)."""
    if devices <= 1:
        return 0.0
    return BARRIER_HOP_US * math.ceil(math.log2(devices))


class ShardDevice:
    """One device: its own machines, fairness cursor, backpressure
    queue, and counters (sched::FusedScheduler twin, as driven by
    shard::ShardGroup)."""

    def __init__(self):
        self.active = []
        self.pending = []
        self.policy = RoundRobin()
        self.steps = 0
        self.launches = 0
        self.work = 0
        self.finished = []  # machines retired since last drain
        self.last = None  # last step's (jobs, live_per_job, launches)
        self.last_widths = None  # last step's per-rider window lengths

    def has_work(self):
        return bool(self.active) or bool(self.pending)

    def has_active_slot(self):
        return len(self.active) < MAX_ACTIVE

    def admit(self, m):
        if self.has_active_slot():
            self.active.append(m)
        else:
            self.pending.append(m)

    def admit_from_queue(self):
        while self.has_active_slot() and self.pending:
            self.active.append(self.pending.pop(0))

    def live_lanes(self):
        total = 0
        for m in self.active:
            cen, lo, hi = m.front()
            total += m.live_in(cen, lo, hi)
        return total

    def tenant_loads(self):
        out = []
        for m in self.active:
            cen, lo, hi = m.front()
            out.append((m, m.live_in(cen, lo, hi)))
        return out

    def step(self):
        """One fused step; returns this step's (live_per_job, launches)
        — the device's StepTrace entry."""
        self.admit_from_queue()
        fronts = []
        for i, m in enumerate(self.active):
            cen, lo, hi = m.front()
            fronts.append((i, hi - lo))
        sel = self.policy.select(fronts)
        live_per_job, jobs, widths, window = [], [], [], 0
        for i in sel:
            m = self.active[i]
            cen, lo, hi = m.front()
            live_per_job.append(m.live_in(cen, lo, hi))
            jobs.append(getattr(m, "job", None))
            widths.append(hi - lo)
            window += hi - lo
        step_launches = launches_for(window)
        # StepTrace twin: what the trace/critical-path layer observes
        self.last = (jobs, list(live_per_job), step_launches)
        self.last_widths = widths
        self.steps += 1
        self.launches += step_launches
        self.work += sum(live_per_job)
        for i in sel:
            self.active[i].step()
        pos = 0
        while pos < len(self.active):
            if self.active[pos].front() is None:
                self.finished.append(self.active.pop(pos))
                self.policy.retire(pos)
            else:
                pos += 1
        self.admit_from_queue()
        return live_per_job, step_launches


WINDOW = 8  # RebalanceCfg::default().window / `trees trace --window`


class CriticalWindow:
    """trace::critical::CriticalWindow twin. Each pushed group epoch
    banks the straggler device's per-tenant compute edges (lane-share
    attribution of the device's modeled fused-epoch cost); owner() is
    the (device, job) pair with the most banked critical µs over the
    window, ties to the smallest key."""

    def __init__(self, window=WINDOW):
        self.window = max(window, 1)
        self.entries = []  # one [(device, job, us), ...] per epoch

    def push(self, per_dev):
        """per_dev: per device None (idle) or the ShardDevice.last
        tuple (jobs, live_per_job, launches)."""
        seg = []
        straggler, best = None, 0.0
        for d, e in enumerate(per_dev):
            if e is None or not e[0]:
                continue
            us = fused_epoch_us(e[1]) + (e[2] - 1) * LAUNCH_US
            if straggler is None or us > best:
                straggler, best = d, us
        if straggler is not None:
            jobs, live, _ = per_dev[straggler]
            total = sum(live)
            for j, l in zip(jobs, live):
                share = l / total if total > 0 else 1.0 / len(jobs)
                seg.append((straggler, j, best * share))
        self.entries.append(seg)
        while len(self.entries) > self.window:
            self.entries.pop(0)

    def owner(self):
        acc, total = {}, 0.0
        for seg in self.entries:
            for d, j, us in seg:
                acc[(d, j)] = acc.get((d, j), 0.0) + us
                total += us
        best = None
        for k in sorted(acc):
            if best is None or acc[k] > best[1]:
                best = (k, acc[k])
        if best is None:
            return None
        (d, j), us = best
        return dict(device=d, job=j, us=us,
                    share=us / total if total > 0.0 else 0.0)


class Rebalancer:
    """shard::balance::Rebalancer twin: at most one migration per
    boundary; trigger max > mean * skew; strict gap improvement. Under
    mode="critical-path" the migrant preference goes to the tenant the
    CriticalWindow attributes the recent critical path to (when it
    lives on the overloaded device and passes the same gap-shrinking
    guards), falling back to the static gap-evening pick."""

    def __init__(self, enabled=True, skew=SKEW_THRESHOLD, cooldown=COOLDOWN,
                 mode="skew", window=WINDOW):
        self.enabled = enabled
        self.skew = skew
        self.cooldown = cooldown
        self.steps_since = cooldown
        self.mode = mode
        self.win = CriticalWindow(window) if mode == "critical-path" else None

    def observe(self, per_dev):
        """Rebalancer::observe twin — no-op outside critical-path."""
        if self.win is not None:
            self.win.push(per_dev)

    def plan(self, loads, devs, alive=None):
        live = [d for d in range(len(loads))
                if alive is None or alive[d]]
        if not self.enabled or len(live) < 2:
            return None
        if self.steps_since < self.cooldown:
            self.steps_since += 1
            return None
        total = sum(loads[d] for d in live)
        if total == 0:
            return None
        src = max(live, key=lambda d: loads[d])
        dst = min(live, key=lambda d: loads[d])
        mean = total / len(live)
        if loads[src] <= mean * max(self.skew, 1.0):
            return None
        if not devs[dst].has_active_slot():
            return None
        tenants = devs[src].tenant_loads()
        if len(tenants) < 2:
            return None
        gap0 = loads[src] - loads[dst]
        if self.win is not None:
            o = self.win.owner()
            if o is not None and o["device"] == src:
                hit = next((t for t in tenants
                            if getattr(t[0], "job", None) == o["job"]),
                           None)
                if hit is not None:
                    m, load = hit
                    if 0 < load < gap0 and \
                            abs((loads[src] - load)
                                - (loads[dst] + load)) < gap0:
                        self.steps_since = 0
                        return m, src, dst
        best = None
        for m, load in tenants:
            if load == 0 or load >= gap0:
                continue
            new_gap = abs((loads[src] - load) - (loads[dst] + load))
            if new_gap < (gap0 if best is None else best[1]):
                best = (m, new_gap)
        if best is None:
            return None
        self.steps_since = 0
        return best[0], src, dst


def run_sharded(tokens, devices, placement="rr", pins=None, rebalance=True,
                mode="skew", trace_out=None):
    """shard::ShardGroup twin: lock-step group epochs over per-device
    fused schedulers, modeled via DeviceGroup (max-over-devices +
    barrier per step). `mode` picks the rebalancer's migrant policy
    ("skew" | "critical-path"); `trace_out` (a list) collects each
    group epoch's per-device trace tuples — the GroupStepTrace twin
    the rust/src/trace analyzer replays."""
    machines = [build(t) for t in tokens]
    for i, m in enumerate(machines):
        m.job = i  # JobId twin: admission order
    devs = [ShardDevice() for _ in range(devices)]
    pins = dict(pins) if pins else {}
    rr_next = 0
    for tok, m in zip(tokens, machines):
        app = tok.split(":")[0]
        if placement == "affinity":
            if app not in pins:
                pins[app] = rr_next % devices
                rr_next += 1
            d = pins[app]
        else:
            d = rr_next % devices
            rr_next += 1
        devs[d].admit(m)
    bal = Rebalancer(enabled=rebalance, mode=mode)
    steps = migrations = 0
    us = peak_imb = 0.0
    while any(d.has_work() for d in devs):
        dev_us, per_dev = [], []
        for d in devs:
            if d.has_work():
                live_per_job, launches = d.step()
                dev_us.append(fused_epoch_us(live_per_job)
                              + (launches - 1) * LAUNCH_US)
                per_dev.append(d.last)
            else:
                dev_us.append(0.0)
                per_dev.append(None)
        steps += 1
        us += max(dev_us) + barrier_us(devices)
        bal.observe(per_dev)  # before plan(), as in ShardGroup::step
        if trace_out is not None:
            trace_out.append(per_dev)
        if devices > 1:  # nothing to balance (or measure) solo
            loads = [d.live_lanes() for d in devs]
            if sum(loads) > 0:
                peak_imb = max(peak_imb,
                               max(loads) / (sum(loads) / len(loads)))
            plan = bal.plan(loads, devs)
            if plan is not None:
                m, src, dst = plan
                pos = devs[src].active.index(m)
                devs[src].active.pop(pos)
                devs[src].policy.retire(pos)
                devs[dst].admit(m)
                migrations += 1
    return dict(steps=steps,
                launches=sum(d.launches for d in devs),
                max_dev=max(d.launches for d in devs),
                work=sum(d.work for d in devs),
                migrations=migrations, us=us, imb=peak_imb)


# ------------------------------- fault twins (rust/src/fault + seams)

MAX_RETRIES, BASE_BACKOFF_US = 3, 5.0  # fault::RetryCfg::default()


class FaultyGroup:
    """shard::ShardGroup twin with the fault seams of ISSUE 6: events
    fire at group-epoch boundaries (`at_step <= group_steps`, i.e.
    before the group's at_step'th epoch), deaths evacuate every
    resident tenant to the least-loaded live device, transients pay a
    bounded exponential backoff (and escalate to a death past the retry
    budget), and each step is priced with the *shrunk* barrier plus one
    re-launch (LAUNCH_US) per evacuated tenant that landed on a
    survivor — `shard::stats::group_step_cost_us`."""

    def __init__(self, devices, events=()):
        self.devs = [ShardDevice() for _ in range(devices)]
        self.alive = [True] * devices
        # (at_step, device, kind, failures) with kind in {die, flaky}
        self.events = sorted(events, key=lambda e: e[0])
        self.cursor = 0
        self.place_next = 0  # Placement::RoundRobin twin
        self.bal = Rebalancer()
        self.steps = 0
        self.us = 0.0
        self.at_us = [0.0]  # modeled time after k group epochs
        self.deaths = self.evacuations = self.retries = 0
        self.backoff_total = 0.0
        self.dead_ended = []
        self.pending_relaunch = 0  # received evacs awaiting their step
        self.busy = [0.0] * devices  # per-device modeled busy µs

    def alive_count(self):
        return sum(self.alive)

    def first_alive_from(self, want):
        n = len(self.devs)
        for d in list(range(want, n)) + list(range(want)):
            if self.alive[d]:
                return d
        return None

    def submit(self, m):
        want = self.place_next % len(self.devs)
        self.place_next += 1
        d = self.first_alive_from(want)
        if d is None:  # fully dead group: the job dead-ends, no hang
            self.evacuations += 1
            self.dead_ended.append(m)
            return
        self.devs[d].admit(m)

    def least_loaded_alive(self):
        best = None
        for d, dev in enumerate(self.devs):
            if not self.alive[d]:
                continue
            key = (dev.live_lanes(), len(dev.active) + len(dev.pending), d)
            if best is None or key < best[0]:
                best = (key, d)
        return None if best is None else best[1]

    def kill(self, d):
        if not self.alive[d]:
            return
        self.alive[d] = False
        self.deaths += 1
        dev = self.devs[d]
        tenants = dev.active + dev.pending
        dev.active, dev.pending = [], []
        dev.policy = RoundRobin()
        for m in tenants:
            to = self.least_loaded_alive()
            self.evacuations += 1
            if to is None:
                self.dead_ended.append(m)
            else:
                self.devs[to].admit(m)
                # the survivor re-launches the displaced tenant: one
                # LAUNCH_US on the boundary's step (dead-ends are free)
                self.pending_relaunch += 1

    def inject(self):
        """Fire due events; returns this boundary's backoff µs."""
        paid_us = 0.0
        while self.cursor < len(self.events) \
                and self.events[self.cursor][0] <= self.steps:
            _, d, kind, failures = self.events[self.cursor]
            self.cursor += 1
            if d >= len(self.devs) or not self.alive[d]:
                continue
            if kind == "die":
                self.kill(d)
            else:  # flaky: bounded retry, then escalation
                paid = min(failures, MAX_RETRIES)
                self.retries += paid
                b = BASE_BACKOFF_US * ((1 << paid) - 1)
                self.backoff_total += b
                paid_us += b
                if failures > MAX_RETRIES:
                    self.kill(d)
        return paid_us

    def has_work(self):
        return any(d.has_work() for d in self.devs)

    def step(self):
        """One lock-step group epoch (ShardGroup::step twin). Returns
        (progressed, machines that finished this epoch)."""
        backoff = self.inject()
        if not self.has_work():
            return False, []
        evac_us = self.pending_relaunch * LAUNCH_US
        self.pending_relaunch = 0
        dev_us, finished = [], []
        for dev in self.devs:
            if dev.has_work():
                live_per_job, launches = dev.step()
                dev_us.append(fused_epoch_us(live_per_job)
                              + (launches - 1) * LAUNCH_US)
                finished.extend(dev.finished)
                dev.finished = []
            else:
                dev_us.append(0.0)
        for d, u in enumerate(dev_us):
            self.busy[d] += u
        self.steps += 1
        self.us += max(dev_us) + barrier_us(self.alive_count()) \
            + backoff + evac_us
        self.at_us.append(self.us)
        if self.alive_count() > 1:
            loads = [d.live_lanes() for d in self.devs]
            plan = self.bal.plan(loads, self.devs, self.alive)
            if plan is not None:
                m, src, dst = plan
                pos = self.devs[src].active.index(m)
                self.devs[src].active.pop(pos)
                self.devs[src].policy.retire(pos)
                self.devs[dst].admit(m)
        return True, finished


MIXES = [
    ("4x fib:16", ["fib:16"] * 4),
    ("8x fib:14", ["fib:14"] * 8),
    ("trio fib+bfs+msort", ["fib:16", "bfs:5", "mergesort:256"]),
    ("2x trio", ["fib:16", "fib:14", "bfs:5", "bfs:6",
                 "mergesort:256", "mergesort:128"]),
    ("8-job mixed", ["fib:18", "fib:16", "bfs:6", "bfs:7", "mergesort:512",
                     "mergesort:256", "nqueens:6", "nqueens:5"]),
]


SHARD_MIXES = [
    ("16x fib:16", ["fib:16"] * 16),
    ("16-job mixed",
     ["fib:16", "fib:16", "fib:14", "fib:14",
      "mergesort:256", "mergesort:256", "mergesort:128", "mergesort:128",
      "bfs:5", "bfs:5", "bfs:6", "bfs:6",
      "nqueens:6", "nqueens:6", "nqueens:5", "nqueens:5"]),
]


# rust/benches/bench_serve.rs twin: the same 12-arrival online feed on
# 4 devices ("bfs:5" here is "bfs:grid:5" in the Rust spec grammar).
# fib:18 runs far past the last arrival, so the group never idles and
# session epochs stay aligned with the group trace.
SERVE_DEVICES = 4
SERVE_FEED = [
    ("fib:18", 0), ("fib:16", 2), ("mergesort:256", 4), ("bfs:5", 6),
    ("nqueens:6", 8), ("fib:14", 10), ("mergesort:128", 12), ("fib:15", 14),
    ("fib:16", 16), ("bfs:6", 18), ("nqueens:5", 20), ("mergesort:256", 22),
]
SERVE_PLANS = [
    ("fault-free", "", []),
    ("1 death", "die:3@6", [(6, 3, "die", 0)]),
    ("2 deaths", "die:3@6,die:2@12", [(6, 3, "die", 0), (12, 2, "die", 0)]),
]


def percentile(sorted_vals, p):
    """Nearest-rank, round-half-away like Rust f64::round."""
    if not sorted_vals:
        return 0.0
    idx = int(math.floor((len(sorted_vals) - 1) * p / 100.0 + 0.5))
    return sorted_vals[min(idx, len(sorted_vals) - 1)]


def run_serve(events=()):
    """Session::run_feed twin on a FaultyGroup: arrivals are admitted
    once the epoch clock reaches their step; completions are stamped
    with the epoch count *after* the step that retired them."""
    g = FaultyGroup(SERVE_DEVICES, events)
    admits, dones = {}, {}
    nxt = 0
    while True:
        while nxt < len(SERVE_FEED) and SERVE_FEED[nxt][1] <= g.steps:
            m = build(SERVE_FEED[nxt][0])
            m.job = nxt
            admits[nxt] = g.steps
            g.submit(m)
            nxt += 1
        progressed, finished = g.step()
        for m in finished:
            dones[m.job] = g.steps
        if not progressed:
            assert nxt >= len(SERVE_FEED), "feed must keep the group busy"
            break
    lat_by_job = {j: g.at_us[dones[j]] - g.at_us[admits[j]]
                  for j in dones}
    lat = sorted(lat_by_job.values())
    return dict(jobs=len(dones), steps=g.steps, us=g.us,
                p50=percentile(lat, 50.0), p99=percentile(lat, 99.0),
                jps=len(dones) / (g.us / 1e6),
                deaths=g.deaths, evac=g.evacuations, retries=g.retries,
                backoff=g.backoff_total,
                work=sum(d.work for d in g.devs),
                lat_by_job=lat_by_job, busy=list(g.busy),
                dead_ends=len(g.dead_ended))


def fault_table():
    print("\nE-FAULT-1 — 12-job online feed, 4 devices, injected faults "
          "(bench_serve twin)")
    hdr = ("| plan | group epochs | deaths | evacuations | retries | "
           "backoff (µs) | p50 (µs) | p99 (µs) | jobs/s | total (µs) | "
           "overhead |")
    print(hdr)
    print("|" + "---|" * 11)
    points = []
    for name, plan_str, events in SERVE_PLANS:
        r = run_serve(events)
        points.append((name, plan_str, r))
    base = points[0][2]
    for name, _, r in points:
        # faults move work, never change it: survivors replay the same
        # machines, so total work T1 is identical across plans
        assert r["work"] == base["work"], (name, r["work"], base["work"])
        assert r["jobs"] == len(SERVE_FEED), name
        # deaths cannot make the run cheaper: every received evacuation
        # bills one re-launch, so faulty plans sit at >= 1.0x (ISSUE 8a)
        assert r["us"] >= base["us"] - 1e-9, (name, r["us"], base["us"])
        print(f"| {name} | {r['steps']} | {r['deaths']} | {r['evac']} | "
              f"{r['retries']} | {r['backoff']:.0f} | {r['p50']:.0f} | "
              f"{r['p99']:.0f} | {r['jps']:.0f} | {r['us']:.0f} | "
              f"{r['us'] / base['us']:.2f}x |")

    # transient faults: bounded retries, no deaths, backoff in the bill
    flaky = run_serve([(3, 0, "flaky", 2), (9, 1, "flaky", 1)])
    assert (flaky["deaths"], flaky["retries"]) == (0, 3)
    assert abs(flaky["backoff"] - (15.0 + 5.0)) < 1e-9
    print(f"\ntransient demo (flaky:0@3:x2, flaky:1@9:x1): {flaky['retries']} "
          f"retries, {flaky['backoff']:.0f} µs backoff, 0 deaths — "
          f"{flaky['us']:.0f} µs total (x{flaky['us'] / base['us']:.2f} "
          f"vs fault-free)")

    # snapshot for the perf trajectory (schema matches bench_serve.rs)
    out = {
        "bench": "serve",
        "devices": SERVE_DEVICES,
        "plans": [
            {
                "name": name,
                "fault_plan": plan_str,
                "jobs": r["jobs"],
                "group_steps": r["steps"],
                "total_us": round(r["us"], 3),
                "p50_us": round(r["p50"], 3),
                "p99_us": round(r["p99"], 3),
                "jobs_per_sec": round(r["jps"], 3),
                "device_deaths": r["deaths"],
                "evacuations": r["evac"],
                "launch_retries": r["retries"],
                "overhead_vs_fault_free": round(r["us"] / base["us"], 4),
            }
            for name, plan_str, r in points
        ],
    }
    path = os.path.abspath(os.path.join(
        os.path.dirname(__file__), "..", "..", "BENCH_serve.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


# ------------------------------- metrics twins (rust/src/metrics)

HIST_BUCKETS = 24  # metrics::HIST_BUCKETS


def hist_bucket(v):
    """metrics::Hist::bucket_of twin: bucket 0 holds v < 1, bucket i
    holds 2^(i-1) <= v < 2^i, the last bucket is the overflow sink."""
    if v < 1.0:
        return 0
    return min(int(math.floor(math.log2(v))) + 1, HIST_BUCKETS - 1)


class HistTwin:
    """metrics::Hist twin — fixed log2 buckets, no rebinning."""

    def __init__(self):
        self.buckets = [0] * HIST_BUCKETS
        self.count = 0
        self.sum = 0.0

    def observe(self, v):
        self.buckets[hist_bucket(v)] += 1
        self.count += 1
        self.sum += v

    def occupied(self):
        return [i for i, b in enumerate(self.buckets) if b > 0]


def obs_table():
    """E-OBS-1: the flight-recorder metrics registry twin over the
    bench_serve feed — per-plan SLO counters, per-app log2 latency
    histograms, and per-device utilization gauges, computed exactly as
    metrics::Registry folds the epoch + outcome records."""
    print("\nE-OBS-1 — flight-recorder metrics twin over the serve feed "
          "(rust/src/metrics mirror)")
    hdr = ("| plan | outcome_done | deadline_miss | evac re-launches | "
           "dead-end | lat mean (µs) | lat max (µs) | lat_us buckets | "
           "util d0..d3 |")
    print(hdr)
    print("|" + "---|" * 9)
    for name, _, events in SERVE_PLANS:
        r = run_serve(events)
        hist = HistTwin()
        per_app = {}
        for j, lat in sorted(r["lat_by_job"].items()):
            hist.observe(lat)
            app = SERVE_FEED[j][0].split(":")[0]
            per_app.setdefault(app, HistTwin()).observe(lat)
        # counter conservation: every outcome lands in exactly one
        # global bucket, and the per-app histograms partition it
        assert hist.count == r["jobs"]
        assert sum(h.count for h in per_app.values()) == hist.count
        relaunches = r["evac"] - r["dead_ends"]
        util = [b / r["us"] for b in r["busy"]]
        occ = hist.occupied()
        span = f"{occ[0]}..{occ[-1]}" if occ else "-"
        print(f"| {name} | {r['jobs']} | 0 | {relaunches} | "
              f"{r['dead_ends']} | {hist.sum / hist.count:.0f} | "
              f"{max(r['lat_by_job'].values()):.0f} | {span} | "
              + " ".join(f"{u:.2f}" for u in util) + " |")
    print("(deadline_miss is 0 by construction: the serve feed carries "
          "no deadlines; the `dD` job-token suffix exercises the "
          "counter live)")


def fuse_table():
    rows = []
    for name, tokens in MIXES:
        solo = run_solo(tokens)
        fused = run_fused(tokens)
        assert fused["work"] == solo["work"], (name, fused, solo)
        assert fused["launches"] < solo["launches"], name
        rows.append((name, len(tokens), solo, fused))

    print("E-FUSE-1 — fused vs N solo runs")
    hdr = ("| mix | jobs | work T1 | solo launches | fused launches | "
           "launches saved | solo syncs | fused epochs | V∞ saved (µs) | "
           "solo APU (µs) | fused APU (µs) | speedup |")
    print(hdr)
    print("|" + "---|" * 12)
    for name, k, s, f in rows:
        saved = s["launches"] - f["launches"]
        print(f"| {name} | {k} | {s['work']} | {s['launches']} | "
              f"{f['launches']} | {saved} ({100 * saved / s['launches']:.0f}%) | "
              f"{s['syncs']} | {f['steps']} | {saved * LAUNCH_US:.0f} | "
              f"{s['us']:.0f} | {f['us']:.0f} | "
              f"{s['us'] / f['us']:.2f}x |")


def shard_table():
    print("\nE-SHARD-1 — sharded 1..8 devices (round-robin placement, "
          "rebalance on)")
    hdr = ("| mix | devices | group epochs | launches | max dev launches | "
           "migrations | peak imbalance | group APU (µs) | vs solo | "
           "vs 1 device |")
    print(hdr)
    print("|" + "---|" * 10)
    for name, tokens in SHARD_MIXES:
        solo = run_solo(tokens)
        one = run_sharded(tokens, 1)
        assert one["work"] == solo["work"], (name, one, solo)
        for devices in (1, 2, 4, 8):
            r = one if devices == 1 else run_sharded(tokens, devices)
            assert r["work"] == solo["work"], (name, devices, r, solo)
            imb = max(r["imb"], 1.0)  # solo groups are balanced by definition
            print(f"| {name} | {devices} | {r['steps']} | {r['launches']} | "
                  f"{r['max_dev']} | {r['migrations']} | {imb:.2f}x | "
                  f"{r['us']:.0f} | {solo['us'] / r['us']:.2f}x | "
                  f"{one['us'] / r['us']:.2f}x |")

    # forced skew: app-affinity pins six long fibs opposite one quick
    # sort; once the sort drains, the loaded device is still
    # turn-taking under its window budget while the other idles — the
    # rebalancer must migrate fibs over.
    tokens = ["fib:16"] * 6 + ["mergesort:16"]
    pinned = run_sharded(tokens, 2, placement="affinity",
                         pins={"fib": 0, "mergesort": 1})
    frozen = run_sharded(tokens, 2, placement="affinity",
                         pins={"fib": 0, "mergesort": 1}, rebalance=False)
    assert pinned["migrations"] >= 1, pinned
    assert frozen["migrations"] == 0
    assert pinned["work"] == frozen["work"]
    print(f"\nskew demo (6x fib:16 pinned to d0, mergesort:16 to d1, "
          f"2 devices): rebalance on -> {pinned['migrations']} migrations, "
          f"{pinned['steps']} group epochs, {pinned['us']:.0f} µs | "
          f"rebalance off -> {frozen['steps']} epochs, {frozen['us']:.0f} µs "
          f"(x{frozen['us'] / pinned['us']:.2f} slower, peak imbalance "
          f"{frozen['imb']:.2f}x vs {pinned['imb']:.2f}x)")


# E-TRACE-1 runs the policy comparison on the E-SHARD-1 forced-skew
# mix: six long fibs pinned to d0 opposite one quick sort on d1.
TRACE_TOKENS = ["fib:16"] * 6 + ["mergesort:16"]
TRACE_PINS = {"fib": 0, "mergesort": 1}
TRACE_MIX = "6x fib:16 pinned d0 + mergesort:16 pinned d1"


def trace_table():
    print("\nE-TRACE-1 — trace-guided (critical-path) vs skew-threshold "
          "rebalancing, forced-skew mix, 2 devices (bench_trace twin)")
    trace = []
    runs = []
    for name, kw in (
        ("no-rebalance", dict(rebalance=False)),
        ("skew-threshold", {}),
        ("critical-path", dict(mode="critical-path", trace_out=trace)),
    ):
        r = run_sharded(TRACE_TOKENS, 2, placement="affinity",
                        pins=dict(TRACE_PINS), **kw)
        runs.append((name, r))
    base, skew, crit = (r for _, r in runs)
    for name, r in runs:
        # the policy decides when/where, never what: same total work
        assert r["work"] == base["work"], (name, r["work"], base["work"])
    # the acceptance bar: trace-guided matches-or-beats the static pick
    assert crit["us"] <= skew["us"] + 1e-9, (crit["us"], skew["us"])

    hdr = ("| policy | group epochs | migrations | peak imbalance | "
           "modeled APU (µs) | vs skew-threshold |")
    print(hdr)
    print("|" + "---|" * 6)
    for name, r in runs:
        print(f"| {name} | {r['steps']} | {r['migrations']} | "
              f"{max(r['imb'], 1.0):.2f}x | {r['us']:.0f} | "
              f"{r['us'] / skew['us']:.2f}x |")

    # analyzer overhead twin: replay the recorded group trace through a
    # fresh CriticalWindow (the per-epoch work `trees trace` adds)
    win = CriticalWindow()
    t0 = time.perf_counter()
    for per_dev in trace:
        win.push(per_dev)
    ns = (time.perf_counter() - t0) * 1e9 / max(len(trace), 1)
    edges = sum(
        sum(len(e[0]) + 1 for e in per_dev if e is not None)
        for per_dev in trace
    ) + crit["migrations"]

    # flight-recorder twin: fold every recorded epoch into the metrics
    # counters + the cost-decomposition invariant — the per-epoch work
    # `--invariants warn` adds on top of the stream itself
    counters = {"epochs": 0, "launches": 0}
    cost_hist = HistTwin()
    t1 = time.perf_counter()
    cum = 0.0
    for per_dev in trace:
        counters["epochs"] += 1
        dev_us = [0.0 if e is None
                  else fused_epoch_us(e[1]) + (e[2] - 1) * LAUNCH_US
                  for e in per_dev]
        counters["launches"] += sum(
            e[2] for e in per_dev if e is not None)
        cost = max(dev_us) + barrier_us(2)
        cost_hist.observe(cost)
        cum += cost
    ns2 = (time.perf_counter() - t1) * 1e9 / max(len(trace), 1)
    assert counters["epochs"] == len(trace)
    print(f"\nanalyzer: {edges} PAG edges over {len(trace)} epochs, "
          f"~{ns:.0f} ns/epoch + recorder fold ~{ns2:.0f} ns/epoch "
          f"(python twin; bench_trace measures the Rust analyzer)")

    out = {
        "bench": "trace",
        "devices": 2,
        "mix": TRACE_MIX,
        "policies": [
            {
                "name": name,
                "group_steps": r["steps"],
                "migrations": r["migrations"],
                "peak_imbalance": round(max(r["imb"], 1.0), 4),
                "modeled_us": round(r["us"], 3),
                "vs_skew_threshold": round(r["us"] / skew["us"], 4),
            }
            for name, r in runs
        ],
        "analyzer": {
            "pag_edges": edges,
            "epochs": len(trace),
            "ns_per_epoch": round(ns, 1),
            "recorder_ns_per_epoch": round(ns2, 1),
        },
    }
    path = os.path.abspath(os.path.join(
        os.path.dirname(__file__), "..", "..", "BENCH_trace.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


# ------------------------------- hybrid twins (rust/src/hybrid)

CPU_WORKERS = 8  # hybrid::CpuModel::default()
CPU_PER_TASK_US = 0.5
CPU_DISPATCH_US = 0.5
CPU_STEAL_US = 0.2
CROSSOVER_MARGIN = 1.25  # hybrid::DEFAULT_MARGIN


def cpu_epoch_us(live):
    """hybrid::CpuModel::epoch_us twin: one pool dispatch, a log-depth
    steal ramp, then ceil(live/workers) rounds of task work."""
    if live == 0:
        return 0.0
    return (CPU_DISPATCH_US + CPU_STEAL_US * math.log2(CPU_WORKERS)
            + math.ceil(live / CPU_WORKERS) * CPU_PER_TASK_US)


class HybridRouter:
    """hybrid::Router twin: greedy peel off the all-GPU window
    (narrowest first, by marginal fused cost), bulk fallback for
    all-narrow windows, hysteresis by `margin` inside a never-worse
    envelope. No pins here — every interp rider is cpu-capable."""

    def __init__(self, mode, margin=CROSSOVER_MARGIN):
        self.mode = mode
        self.margin = max(margin, 1.0)
        self.last = {}  # job -> "cpu" | "gpu"

    def route(self, fronts):
        """fronts: [(job, live), ...] in slice order; returns a
        parallel list of "cpu"/"gpu"."""
        if self.mode == "cpu":
            kinds = ["cpu"] * len(fronts)
        elif self.mode == "gpu":
            kinds = ["gpu"] * len(fronts)
        else:
            kinds = self.route_auto(fronts)
        for (job, _), k in zip(fronts, kinds):
            self.last[job] = k
        return kinds

    def plan_cost(self, fronts, kinds):
        gpu_lives = [l for (_, l), k in zip(fronts, kinds) if k == "gpu"]
        cost = sum(cpu_epoch_us(l)
                   for (_, l), k in zip(fronts, kinds) if k == "cpu")
        if gpu_lives:
            cost += fused_epoch_us(gpu_lives)
        return cost

    def route_auto(self, fronts):
        plan = self.greedy_plan(fronts, True)
        # never-worse envelope: if hysteresis held a side past the
        # crossover, drop the history for this epoch
        pure = self.plan_cost(fronts, ["gpu"] * len(fronts))
        if self.plan_cost(fronts, plan) > pure + 1e-9:
            return self.greedy_plan(fronts, False)
        return plan

    def greedy_plan(self, fronts, with_history):
        kinds = ["gpu"] * len(fronts)
        on_gpu = [True] * len(fronts)

        def gpu_cost():
            lives = [l for (_, l), g in zip(fronts, on_gpu) if g]
            return fused_epoch_us(lives) if lives else 0.0

        order = sorted(range(len(fronts)),
                       key=lambda i: (fronts[i][1], fronts[i][0]))
        for i in order:
            job, live = fronts[i]
            with_us = gpu_cost()
            on_gpu[i] = False
            delta = max(with_us - gpu_cost(), 0.0)
            cpu_us = cpu_epoch_us(live)
            prev = self.last.get(job) if with_history else None
            if prev == "cpu":
                to_cpu = cpu_us <= delta * self.margin
            elif prev == "gpu":
                to_cpu = cpu_us * self.margin < delta
            else:
                to_cpu = cpu_us < delta
            if to_cpu:
                kinds[i] = "cpu"  # stays off the GPU window
            else:
                on_gpu[i] = True
        # bulk fallback: in an all-narrow window every marginal is ~0,
        # but moving the whole set sheds the launch entirely
        remaining = [i for i in range(len(fronts)) if on_gpu[i]]
        if remaining:
            fused = gpu_cost()
            sum_cpu = sum(cpu_epoch_us(fronts[i][1]) for i in remaining)
            settled_gpu = with_history and any(
                self.last.get(fronts[i][0]) == "gpu" for i in remaining)
            wins = (sum_cpu * self.margin < fused if settled_gpu
                    else sum_cpu < fused)
            if wins:
                for i in remaining:
                    kinds[i] = "cpu"
        return kinds

    def retire(self, job):
        self.last.pop(job, None)


def run_hybrid(tokens, mode):
    """bench_hybrid run_mode twin: one engine-mode run of a mix, priced
    per step by the shared engine-split arithmetic (CPU riders each pay
    their own pool epoch; GPU riders share one fused launch computed
    over the GPU-routed window only, plus overflow tiles)."""
    dev = ShardDevice()
    for j, t in enumerate(tokens):
        m = build(t)
        m.job = j
        dev.admit(m)
    router = HybridRouter(mode)
    us, steps, cpu_epochs, gpu_epochs, widest = 0.0, 0, 0, 0, 0
    while dev.has_work():
        dev.step()
        jobs, live, _ = dev.last
        widths = dev.last_widths
        kinds = router.route(list(zip(jobs, live)))
        for m in dev.finished:
            router.retire(m.job)
        del dev.finished[:]
        gpu_lives = [l for l, k in zip(live, kinds) if k == "gpu"]
        launches = launches_for(
            sum(w for w, k in zip(widths, kinds) if k == "gpu"))
        us += sum(cpu_epoch_us(l) for l, k in zip(live, kinds)
                  if k == "cpu")
        if gpu_lives:
            us += fused_epoch_us(gpu_lives) \
                + max(launches - 1, 0) * LAUNCH_US
        steps += 1
        for l, k in zip(live, kinds):
            if k == "cpu":
                cpu_epochs += 1
                widest = max(widest, l)
            else:
                gpu_epochs += 1
    return dict(us=us, steps=steps, cpu_epochs=cpu_epochs,
                gpu_epochs=gpu_epochs, widest_cpu=widest)


# The three bench_hybrid mixes ("bfs:4" here is "bfs:grid:4" in the
# Rust spec grammar): all-narrow fronts (launch-bound on the GPU),
# all-wide fronts (launch amortized), and a serve-like blend.
HYBRID_MIXES = [
    ("narrow-front: fib:10 + fib:8 + nqueens:4",
     ["fib:10", "fib:8", "nqueens:4"]),
    ("wide-front: 2x mergesort:1024 + mergesort:512",
     ["mergesort:1024", "mergesort:1024", "mergesort:512"]),
    ("blended serve mix: fibs + bfs edges + sorts",
     ["fib:12", "fib:10", "bfs:4", "bfs:5", "mergesort:256",
      "mergesort:64", "nqueens:5"]),
]


def hybrid_table():
    print("\nE-HYBRID-1 — front-width crossover, --engine cpu/gpu/auto, "
          "1 device (bench_hybrid twin)")
    print("| mix | steps | gpu µs | cpu µs | auto µs | auto vs gpu | "
          "cpu-epochs | widest cpu front |")
    print("|" + "---|" * 8)
    rows = []
    for name, tokens in HYBRID_MIXES:
        gpu = run_hybrid(tokens, "gpu")
        cpu = run_hybrid(tokens, "cpu")
        auto = run_hybrid(tokens, "auto")
        # routing never changes the epoch structure, only the venue
        assert gpu["steps"] == cpu["steps"] == auto["steps"], name
        # E-HYBRID-1 acceptance: auto never loses to pure GPU, and wide
        # (>=512-lane) epochs never leave the fused path
        assert auto["us"] <= gpu["us"] + 1e-9, (name, auto, gpu)
        assert auto["widest_cpu"] < 512, (name, auto)
        speed = gpu["us"] / max(auto["us"], 1e-9)
        rows.append((name, gpu, cpu, auto, speed))
        print(f"| {name} | {gpu['steps']} | {gpu['us']:.0f} | "
              f"{cpu['us']:.0f} | {auto['us']:.0f} | {speed:.2f}x | "
              f"{auto['cpu_epochs']}/{auto['cpu_epochs'] + auto['gpu_epochs']} | "
              f"{auto['widest_cpu']} |")
    narrow_speedup = rows[0][4]
    assert narrow_speedup >= 1.2, narrow_speedup

    out = {
        "bench": "hybrid",
        "devices": 1,
        "crossover_margin": CROSSOVER_MARGIN,
        "mixes": [
            {
                "mix": name,
                "steps": gpu["steps"],
                "gpu_us": round(gpu["us"], 3),
                "cpu_us": round(cpu["us"], 3),
                "auto_us": round(auto["us"], 3),
                "auto_vs_gpu": round(speed, 4),
                "auto_cpu_epochs": auto["cpu_epochs"],
                "auto_gpu_epochs": auto["gpu_epochs"],
                "widest_cpu_front": auto["widest_cpu"],
            }
            for name, gpu, cpu, auto, speed in rows
        ],
    }
    path = os.path.abspath(os.path.join(
        os.path.dirname(__file__), "..", "..", "BENCH_hybrid.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


# ------------------------------- hetero twins (rust/src/shard speeds,
# slice steals, LPT — ISSUE 10, E-HETERO-1)

XFER_LANE_US = 0.01          # simt::DeviceGroup::xfer_lane_us
MIGRATE_STATE_FACTOR = 16.0  # simt::MIGRATE_STATE_FACTOR
HETERO_SPEEDS = [1.0, 0.25]  # bench_hetero's group: reference + 1/4 bin


def steal_xfer_us(lanes):
    """DeviceGroup::steal_xfer_us twin: one signal hop plus the
    per-lane front transfer."""
    return BARRIER_HOP_US + XFER_LANE_US * lanes


def migrate_xfer_us(lanes):
    """DeviceGroup::migrate_xfer_us twin: the whole tenant state moves,
    not just the live front."""
    return BARRIER_HOP_US + XFER_LANE_US * lanes * MIGRATE_STATE_FACTOR


def member_epoch_us(lanes, speed):
    """One slice priced on a `speed`-scaled member
    (DeviceGroup::member + GpuModel::fused_epoch_us twin)."""
    if lanes == 0:
        return 0.0
    return fused_epoch_us([lanes]) / max(speed, 1e-9)


def plan_steal_twin(loads, devs, speeds):
    """balance::Rebalancer::plan_steal twin: the most expensive member
    (modeled µs on its own SKU) lends half its widest front to the
    cheapest member for one epoch — only inside the strict never-worse
    envelope against both no-action and whole-tenant migration."""
    live = [d for d in range(len(loads)) if True]

    def est(d, lanes):
        return member_epoch_us(lanes, speeds[d])

    src = max(live, key=lambda d: est(d, loads[d]))
    dst = min(live, key=lambda d: est(d, loads[d]))
    if src == dst or est(src, loads[src]) <= est(dst, loads[dst]):
        return None
    tenants = devs[src].tenant_loads()
    if not tenants:
        return None
    m, front = max(tenants, key=lambda t: (t[1], -t[0].job))
    if front < 2:
        return None
    half = front // 2

    def total(cost):
        return max(cost(d) for d in live)

    no_action = total(lambda d: est(d, loads[d]))
    stolen = total(lambda d:
                   est(d, loads[d] - half) if d == src
                   else est(d, loads[d]) + est(d, half)
                   + steal_xfer_us(half) if d == dst
                   else est(d, loads[d]))
    migrated = total(lambda d:
                     est(d, loads[d] - front) if d == src
                     else est(d, loads[d] + front)
                     + migrate_xfer_us(front) if d == dst
                     else est(d, loads[d]))
    if stolen < no_action and stolen <= migrated:
        return m, src, dst, half
    return None


class LptRebalancer:
    """balance::Rebalancer twin under RebalanceMode::Lpt: when the
    speed-normalized skew trigger fires, re-pack every tenant largest
    first onto the least-finishing member, executed only when the
    modeled makespan strictly shrinks (headroom never binds at this
    twin's tenant counts)."""

    def __init__(self, speeds, skew=SKEW_THRESHOLD, cooldown=COOLDOWN):
        self.speeds = speeds
        self.skew = skew
        self.cooldown = cooldown
        self.steps_since = cooldown

    def plan_all(self, loads, devs):
        live = list(range(len(loads)))
        if len(live) < 2 or sum(loads) == 0:
            return []
        if self.steps_since < self.cooldown:
            self.steps_since += 1
            return []

        def spd(d):
            return max(self.speeds[d], 1e-9)

        def t(d):
            return loads[d] / spd(d)

        makespan0 = max(t(d) for d in live)
        mean = sum(t(d) for d in live) / len(live)
        if makespan0 <= mean * max(self.skew, 1.0):
            return []
        items = [(m, l, d) for d in live
                 for m, l in devs[d].tenant_loads() if l > 0]
        items.sort(key=lambda it: (-it[1], it[0].job))
        time_ = [0.0] * len(loads)
        assign = []
        for m, l, cur in items:
            best = live[0]
            for d in live[1:]:
                a = time_[d] + l / spd(d)
                b = time_[best] + l / spd(best)
                if a + 1e-9 < b or (abs(a - b) <= 1e-9 and d == cur
                                    and best != cur):
                    best = d
            time_[best] += l / spd(best)
            assign.append((m, cur, best))
        makespan1 = max(time_[d] for d in live)
        if makespan1 + 1e-9 >= makespan0:
            return []
        moves = [(m, cur, want) for m, cur, want in assign if want != cur]
        if moves:
            self.steps_since = 0
        return moves


def run_hetero(tokens, speeds, aware):
    """bench_hetero `run` twin: a lock-step mixed-SKU group, every
    member priced on its own scaled model (cost / speed). `aware`
    switches the planner from speed-blind greedy (the unweighted skew
    Rebalancer) to LPT over speed-normalized loads plus one-epoch
    slice steals; pricing is heterogeneous either way, so the ratio
    isolates what the planner knows, not the hardware."""
    machines = [build(t) for t in tokens]
    for i, m in enumerate(machines):
        m.job = i
    devs = [ShardDevice() for _ in speeds]
    for i, m in enumerate(machines):
        devs[i % len(devs)].admit(m)
    bal = LptRebalancer(speeds) if aware else Rebalancer()
    steps = migrations = steals = 0
    us = 0.0
    while any(d.has_work() for d in devs):
        plan = None
        if aware:
            loads = [d.live_lanes() for d in devs]
            plan = plan_steal_twin(loads, devs, speeds)
        dev_us = [0.0] * len(devs)
        thief_extra = 0.0
        thief = None
        for d, dev in enumerate(devs):
            if not dev.has_work():
                continue
            live_per_job, launches = dev.step()
            kept = list(live_per_job)
            if plan is not None and d == plan[1]:
                m, _src, dst, half = plan
                jobs = dev.last[0]
                if m.job in jobs:
                    k = jobs.index(m.job)
                    got = min(half, kept[k])
                    if got > 0:
                        kept[k] -= got
                        steals += 1
                        thief = dst
                        thief_extra = member_epoch_us(got, speeds[dst]) \
                            + steal_xfer_us(got)
            dev_us[d] = (fused_epoch_us(kept)
                         + (launches - 1) * LAUNCH_US) \
                / max(speeds[d], 1e-9)
        if thief is not None:
            dev_us[thief] += thief_extra
        steps += 1
        us += max(dev_us) + barrier_us(len(devs))
        loads = [d.live_lanes() for d in devs]
        if aware:
            moves = bal.plan_all(loads, devs)
        else:
            one = bal.plan(loads, devs)
            moves = [one] if one is not None else []
        for m, src, dst in moves:
            pos = devs[src].active.index(m)
            devs[src].active.pop(pos)
            devs[src].policy.retire(pos)
            devs[dst].admit(m)
            migrations += 1
    return dict(us=us, steps=steps, migrations=migrations, steals=steals)


# The three bench_hetero mixes: narrow uniform work (little to
# re-pack), equal lanes across unequal SKUs (time skew a lane counter
# cannot see), and a serve-like blend whose wide sorts round-robin
# onto the slow member. The floor is each mix's acceptance ratio.
HETERO_MIXES = [
    ("uniform narrow: four fibs",
     ["fib:12", "fib:10", "fib:11", "fib:9"], 1.0),
    ("time-skewed: equal-lane sorts, 4x-slower member",
     ["mergesort:1024", "mergesort:1024"], 1.2),
    ("blended: wide sorts land on the slow member",
     ["fib:10", "mergesort:2048", "fib:8", "mergesort:512"], 1.0),
]


def hetero_table():
    print("\nE-HETERO-1 — speed-blind greedy vs LPT+steals, 2 devices, "
          "SKUs 1.0/0.25 (bench_hetero twin)")
    print("| mix | blind µs | aware µs | speedup | steps b/a | "
          "migrations b/a | steals |")
    print("|" + "---|" * 7)
    rows = []
    for name, tokens, floor in HETERO_MIXES:
        blind = run_hetero(tokens, HETERO_SPEEDS, aware=False)
        aware = run_hetero(tokens, HETERO_SPEEDS, aware=True)
        speedup = blind["us"] / max(aware["us"], 1e-9)
        # E-HETERO-1 acceptance: speed-aware planning never loses, and
        # wins outright where the skew is invisible to lane counting
        assert speedup >= 1.0 - 1e-9, (name, blind, aware)
        assert speedup >= floor - 1e-9, (name, speedup, floor)
        rows.append((name, blind, aware, speedup))
        print(f"| {name} | {blind['us']:.0f} | {aware['us']:.0f} | "
              f"{speedup:.2f}x | {blind['steps']}/{aware['steps']} | "
              f"{blind['migrations']}/{aware['migrations']} | "
              f"{aware['steals']} |")

    out = {
        "bench": "hetero",
        "devices": len(HETERO_SPEEDS),
        "speeds": HETERO_SPEEDS,
        "mixes": [
            {
                "mix": name,
                "blind_us": round(blind["us"], 3),
                "aware_us": round(aware["us"], 3),
                "speedup": round(speedup, 4),
                "steps_blind": blind["steps"],
                "steps_aware": aware["steps"],
                "migrations_blind": blind["migrations"],
                "migrations_aware": aware["migrations"],
                "steals_aware": aware["steals"],
            }
            for name, blind, aware, speedup in rows
        ],
    }
    path = os.path.abspath(os.path.join(
        os.path.dirname(__file__), "..", "..", "BENCH_hetero.json"))
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


def main():
    fuse_table()
    shard_table()
    fault_table()
    trace_table()
    obs_table()
    hybrid_table()
    hetero_table()


if __name__ == "__main__":
    main()
