"""treeslang: the Task Vector Machine (TVM) expressed as vectorized JAX.

This is Layer 2 of the stack. A TREES application is a `Program`: a set of
`TaskType`s whose bodies are *vectorized* JAX functions over the active
window of the Task Vector. `epoch.make_epoch_step` fuses all task types of
a program into a single epoch-step computation — the paper's "Phase 2"
bulk kernel — which `aot.py` lowers to HLO text for the Rust coordinator.

Encoding (paper §5.1.2, footnote 2):
    code = epoch * num_task_types + task_type      (task_type in 1..T)
    code == 0  =>  invalid entry

Fork allocation uses an exclusive prefix sum (the Pallas scan kernel in
``kernels/scan.py``) instead of the paper's per-wavefront atomic
increment: the deterministic, cooperative (work-together Tenet 2)
equivalent on a vector machine.
"""

from .core import TaskType, Program, Effects, Env, no_effects
from .epoch import make_epoch_step, EpochIO

__all__ = [
    "TaskType",
    "Program",
    "Effects",
    "Env",
    "no_effects",
    "make_epoch_step",
    "EpochIO",
]
