"""Core types of the vectorized TVM DSL.

A task body is a function

    fn(env: Env, args: i32[W, A], mask: bool[W], child_slots: i32[W, K])
        -> Effects

operating on the whole active window at once (SIMT style). `mask` marks
the lanes that hold a live task of this type in the current epoch; the
body must produce well-defined values on masked lanes and garbage is
tolerated (the combinator selects with `where(mask, ...)`) on the rest.

`child_slots[i, k]` is the Task Vector index that lane i's k-th fork will
occupy — the value fork() "returns" in the scalar TVM. Bodies use it to
record children in join args (so a later join can gather the children's
`emit` results from `res`).
"""

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp


@dataclass
class Env:
    """Read-only view of machine state available to a task body."""

    res_win: jnp.ndarray  # i32[W,G] host-pre-gathered emit results: for
    # each lane, the G result values its app-defined gather spec pulls
    # from the host-side res array (child slots stored in join args).
    heap_i: jnp.ndarray  # i32[Hi]  mutable app heap (ints)
    heap_f: jnp.ndarray  # f32[Hf]  mutable app heap (floats)
    const_i: jnp.ndarray  # i32[Ci]  read-only app data
    const_f: jnp.ndarray  # f32[Cf]
    cen: jnp.ndarray  # i32[]    current epoch number
    lo: jnp.ndarray  # i32[]    window start (global TV index of lane 0)
    active: jnp.ndarray  # i32[]    number of in-range lanes
    next_free: jnp.ndarray  # i32[]    allocation cursor at epoch start
    seed: jnp.ndarray  # i32[]    per-epoch seed (annealing etc.)
    lanes: jnp.ndarray  # i32[W]   global TV index of each lane (lo + iota)
    W: int
    N: int


@dataclass
class Effects:
    """What a window of tasks of one type did this epoch (all vectorized).

    Any field may be None, meaning "none of that effect".
    """

    # forks: lane i creates fork_count[i] tasks; the k-th has type
    # fork_type[i,k] (1-based) and args fork_args[i,k,:].
    fork_count: Optional[jnp.ndarray] = None  # i32[W]
    fork_type: Optional[jnp.ndarray] = None  # i32[W,K]
    fork_args: Optional[jnp.ndarray] = None  # i32[W,K,A]
    # join: lane i replaces its own TV entry with <join_type, join_args>,
    # scheduled to re-run when the join stack pops back to this epoch.
    join_mask: Optional[jnp.ndarray] = None  # bool[W]
    join_type: Optional[jnp.ndarray] = None  # i32[W]
    join_args: Optional[jnp.ndarray] = None  # i32[W,A]
    # emit: lane i finishes, storing emit_val[i] in res[lanes[i]].
    emit_mask: Optional[jnp.ndarray] = None  # bool[W]
    emit_val: Optional[jnp.ndarray] = None  # i32[W]
    # map: lane i enqueues map_count[i] data-parallel map descriptors.
    map_count: Optional[jnp.ndarray] = None  # i32[W]
    map_args: Optional[jnp.ndarray] = None  # i32[W,Km,Am]
    # heap scatters: lists of (idx i32[W], val, mask bool[W], op) where
    # op is "set" | "min" | "max" | "add". Bodies read the PRE-epoch heap
    # (env.heap_*); scatters are applied at epoch end. min/max/add are
    # commutative and safe under same-epoch conflicts; "set" requires the
    # app to guarantee unique indices within the epoch.
    heap_i_scatter: List[tuple] = field(default_factory=list)
    heap_f_scatter: List[tuple] = field(default_factory=list)
    # whole-heap updates (task bodies that loop, and map kernels)
    heap_i: Optional[jnp.ndarray] = None  # i32[Hi]
    heap_f: Optional[jnp.ndarray] = None  # f32[Hf]


def no_effects() -> Effects:
    """A task body that does nothing (useful for padding/testing)."""
    return Effects()


@dataclass
class TaskType:
    """One task function of a TREES program.

    `tid` is assigned by `Program` (1-based, matching the paper's
    `taskType` encoding). `max_forks` bounds fork_count for this type and
    sizes the program-wide child_slots K = max over types.
    """

    name: str
    fn: Callable  # (Env, args, mask, child_slots) -> Effects
    max_forks: int = 0
    max_maps: int = 0
    tid: int = field(default=0, init=False)


@dataclass
class Program:
    """A TREES application: task types + static shape configuration."""

    name: str
    task_types: Sequence[TaskType]
    num_args: int  # A: i32 args per task
    map_args: int = 0  # Am: i32 args per map descriptor
    # map kernel: (env-like dict, map_args i32[Wm,Am], mask bool[Wm])
    #   -> (heap_i', heap_f')  — lowered as a separate artifact.
    map_fn: Optional[Callable] = None
    # res gather width G (see Env.res_win) and the host-side gather
    # spec: gather(tid, args_row, res) -> list of G ints. Used by the
    # python host mirror; the Rust coordinator mirrors it natively.
    gather_width: int = 0
    gather: Optional[Callable] = None
    # initial workload is provided by the Rust side; these sizes are
    # baked per size-class at AOT time.

    def __post_init__(self):
        seen = set()
        for i, tt in enumerate(self.task_types):
            tt.tid = i + 1
            if tt.name in seen:
                raise ValueError(f"duplicate task type name {tt.name!r}")
            seen.add(tt.name)

    @property
    def T(self) -> int:
        return len(self.task_types)

    @property
    def K(self) -> int:
        return max((tt.max_forks for tt in self.task_types), default=0)

    @property
    def Km(self) -> int:
        return max((tt.max_maps for tt in self.task_types), default=0)

    # gather width G: how many res values the host pre-gathers per lane
    # (0 for apps that never join-read results). Set via constructor.

    def type_named(self, name: str) -> TaskType:
        for tt in self.task_types:
            if tt.name == name:
                return tt
        raise KeyError(name)

    def encode(self, epoch: int, tid: int) -> int:
        """code = epoch * T + tid (paper footnote 2)."""
        return epoch * self.T + tid


def decode_code(code: jnp.ndarray, T: int):
    """Split packed codes into (epoch, tid); invalid entries get tid 0.

    code > 0:  epoch = (code - 1) // T,  tid = code - epoch * T  (1..T)
    code == 0: invalid.
    """
    valid = code > 0
    epoch = jnp.where(valid, (code - 1) // T, -1)
    tid = jnp.where(valid, code - epoch * T, 0)
    return epoch, tid, valid
