"""Python mirror of the Rust coordinator — test/debug driver.

Runs a Program end-to-end through the *same* epoch-step computation that
gets AOT-lowered, with the host-side logic (join stack, NDRange stack,
CEN, next_free, fork splicing, reclaim) implemented exactly as
`rust/src/coordinator` implements it. pytest uses this to validate the
L2 semantics; the Rust integration tests then validate that the Rust
coordinator drives the identical artifact to the identical states.

Never imported at runtime by anything — build/test only.
"""

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .core import Program
from .epoch import EpochIO, make_epoch_step


@dataclass
class HostState:
    code: np.ndarray
    args: np.ndarray
    res: np.ndarray
    heap_i: np.ndarray
    heap_f: np.ndarray
    const_i: np.ndarray
    const_f: np.ndarray
    next_free: int
    join_stack: List[int] = field(default_factory=list)
    ndrange_stack: List[Tuple[int, int]] = field(default_factory=list)
    epochs: int = 0
    launches: int = 0
    total_active: int = 0  # sum over epochs of live lanes ~= work T1
    map_launches: int = 0


class PyCoordinator:
    """Drives a Program exactly like the Rust coordinator does."""

    def __init__(self, prog: Program, io: EpochIO, *, max_epochs: int = 100000):
        self.prog = prog
        self.io = io
        self.max_epochs = max_epochs
        self.step = jax.jit(make_epoch_step(prog, io))
        self.map_step = (
            jax.jit(self._make_map_step()) if prog.map_fn is not None else None
        )

    def _make_map_step(self):
        prog, io = self.prog, self.io

        def mstep(map_args, heap_i, heap_f, const_i, const_f, nm):
            Wm = map_args.shape[0]
            mask = jnp.arange(Wm, dtype=jnp.int32) < nm
            return prog.map_fn(
                dict(heap_i=heap_i, heap_f=heap_f,
                     const_i=const_i, const_f=const_f),
                map_args, mask)

        return mstep

    def init_state(self, initial_args, heap_i=None, heap_f=None,
                   const_i=None, const_f=None) -> HostState:
        io, prog = self.io, self.prog
        code = np.zeros(io.N, np.int32)
        args = np.zeros((io.N, prog.num_args), np.int32)
        code[0] = prog.encode(0, 1)  # initial task: type 1, epoch 0
        args[0, : len(initial_args)] = initial_args

        def fit(x, n, dt):
            out = np.zeros(n, dt)
            if x is not None:
                x = np.asarray(x, dt)
                out[: len(x)] = x
            return out

        return HostState(
            code=code,
            args=args,
            res=np.zeros(io.N, np.int32),
            heap_i=fit(heap_i, io.Hi, np.int32),
            heap_f=fit(heap_f, io.Hf, np.float32),
            const_i=fit(const_i, io.Ci, np.int32),
            const_f=fit(const_f, io.Cf, np.float32),
            next_free=1,
            join_stack=[0],
            ndrange_stack=[(0, 1)],
        )

    def run(self, st: HostState, seed: int = 0) -> HostState:
        W = self.io.W
        while st.join_stack:
            if st.epochs >= self.max_epochs:
                raise RuntimeError("epoch limit exceeded")
            cen = st.join_stack.pop()
            lo, hi = st.ndrange_stack.pop()
            old_next_free = st.next_free
            join_sched = False
            map_sched = False
            pending_maps = []
            # tile the NDRange across window-sized launches (same CEN)
            tlo = lo
            while tlo < hi:
                active = min(hi - tlo, W)
                wc = np.zeros(W, np.int32)
                wa = np.zeros((W, self.prog.num_args), np.int32)
                wc[:active] = st.code[tlo:tlo + active]
                wa[:active] = st.args[tlo:tlo + active]
                # host-side res pre-gather (mirrors the Rust coordinator)
                G = max(self.prog.gather_width, 1)
                rw = np.zeros((W, G), np.int32)
                if self.prog.gather is not None:
                    T = self.prog.T
                    for i in range(active):
                        code = int(wc[i])
                        if code <= 0:
                            continue
                        tid = code - (code - 1) // T * T
                        rw[i, :] = self.prog.gather(tid, wa[i], st.res)
                scalars = np.array(
                    [cen, tlo, active, st.next_free, seed + st.epochs, 0, 0, 0],
                    np.int32)
                outs = self.step(wc, wa, rw, st.heap_i, st.heap_f,
                                 st.const_i, st.const_f, scalars)
                outs = [np.asarray(o) for o in outs]
                if self.prog.Km > 0:
                    (wc2, wa2, ev, em, hi2, hf2, fcode, fargs, mout,
                     flags) = outs
                else:
                    (wc2, wa2, ev, em, hi2, hf2, fcode, fargs, flags) = outs
                    mout = None
                n_forked, j_any, m_any, n_mapped, _emits, n_live = flags[:6]
                st.code[tlo:tlo + active] = wc2[:active]
                st.args[tlo:tlo + active] = wa2[:active]
                emitted = np.nonzero(em[:active])[0]
                st.res[tlo + emitted] = ev[emitted]
                st.heap_i = hi2
                st.heap_f = hf2
                if n_forked > 0:
                    nf = st.next_free
                    st.code[nf:nf + n_forked] = fcode[:n_forked]
                    st.args[nf:nf + n_forked] = fargs[:n_forked]
                    st.next_free = nf + int(n_forked)
                join_sched |= bool(j_any)
                if m_any:
                    map_sched = True
                    pending_maps.append(mout[: int(n_mapped)])
                st.launches += 1
                st.total_active += int(n_live)
                tlo += active
            st.epochs += 1
            # phase 3: stack updates (order: join first, fork on top)
            if join_sched:
                st.join_stack.append(cen)
                st.ndrange_stack.append((lo, hi))
            if st.next_free > old_next_free:
                st.join_stack.append(cen + 1)
                st.ndrange_stack.append((old_next_free, st.next_free))
            if map_sched:
                self._run_maps(st, pending_maps)
            if (not join_sched and st.next_free == old_next_free
                    and hi == st.next_free):
                st.next_free = lo  # reclaim (paper §5.3 epoch-3 behaviour)
        return st

    def _run_maps(self, st: HostState, pending: List[np.ndarray]):
        Wm = self.io.W * max(self.prog.Km, 1)
        q = np.concatenate(pending, axis=0) if pending else np.zeros(
            (0, max(self.prog.map_args, 1)), np.int32)
        for off in range(0, len(q), Wm):
            chunk = q[off:off + Wm]
            nm = len(chunk)
            buf = np.zeros((Wm, q.shape[1]), np.int32)
            buf[:nm] = chunk
            hi2, hf2 = self.map_step(buf, st.heap_i, st.heap_f,
                                     st.const_i, st.const_f, nm)
            st.heap_i = np.asarray(hi2)
            st.heap_f = np.asarray(hf2)
            st.map_launches += 1
