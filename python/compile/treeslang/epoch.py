"""The epoch-step combinator: fuse a Program's task types into one
bulk computation — the paper's Epoch Phase 2 kernel.

The lowered function has a fixed signature (see EpochIO) per
(window bucket W, capacity class). The Rust coordinator drives it:

  inputs : win_code i32[W], win_args i32[W,A], res_win i32[W,G]
           (host-pre-gathered emit results — the coordinator resolves
           each lane's join-arg slots against its host-side res array,
           so the device I/O is window-proportional, never O(N)),
           heap_i i32[Hi], heap_f f32[Hf], const_i i32[Ci], const_f f32[Cf],
           scalars i32[8] = [cen, lo, active, next_free, seed, 0, 0, 0]
  outputs: win_code', win_args', emit_val i32[W], emit_msk i32[W],
           heap_i', heap_f', fork_code i32[W*K], fork_args i32[W*K, A],
           map_out   i32[W*Km, Am]  (only if program.Km > 0),
           flags i32[8] = [n_forked, join_scheduled, map_scheduled,
                           n_mapped, emit_count, n_active, 0, 0]

Semantics per paper §4.3/§5.2:
  * a lane is active iff in range, code valid, and its epoch == CEN;
  * fork  -> new entries, epoch CEN+1, slots next_free + scan offset,
             returned compacted in fork_code/fork_args (the Rust side
             splices them at next_free — contiguity per §5.1.2 obs. 2);
  * join  -> lane's own entry replaced, SAME epoch number (re-runs when
             the join stack pops back to CEN);
  * emit  -> result stored in res[lane], entry invalidated;
  * map   -> descriptor enqueued, run by the coordinator after the epoch
             (paper §5.2.4: map kernel completes before next Phase 1).
"""

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .core import Program, Env, Effects, decode_code
from ..kernels.scan import exclusive_scan


@dataclass
class EpochIO:
    """Static shape configuration for one lowered epoch-step artifact.

    `N` is the host-side TV capacity (how many slots the coordinator may
    allocate); `R` is the on-device result buffer length. Apps that
    `emit`/gather results need `R == N`; pure fork-forward apps (BFS,
    SSSP) set `R = 1` so the result buffer costs nothing per launch.
    """

    W: int  # window bucket (lanes per launch)
    N: int  # TV capacity (host-side slots)
    Hi: int  # mutable int heap length   (>=1)
    Hf: int  # mutable float heap length (>=1)
    Ci: int  # const int length          (>=1)
    Cf: int  # const float length        (>=1)
    R: int = 1  # kept in the manifest for host res sizing; unused here

    def input_specs(self, prog: Program):
        i32, f32 = jnp.int32, jnp.float32
        S = jax.ShapeDtypeStruct
        G = max(prog.gather_width, 1)
        return (
            S((self.W,), i32),  # win_code
            S((self.W, prog.num_args), i32),  # win_args
            S((self.W, G), i32),  # res_win (host-pre-gathered)
            S((self.Hi,), i32),  # heap_i
            S((self.Hf,), f32),  # heap_f
            S((self.Ci,), i32),  # const_i
            S((self.Cf,), f32),  # const_f
            S((8,), i32),  # scalars
        )


def _sel(mask, a, b):
    if a is None:
        return b
    return jnp.where(mask, a, b)


def make_epoch_step(prog: Program, io: EpochIO):
    """Build the fused epoch-step function for `prog` at shapes `io`."""

    W, N = io.W, io.N
    A, T = prog.num_args, prog.T
    K = max(prog.K, 1)
    Km = max(prog.Km, 1)
    Am = max(prog.map_args, 1)
    i32 = jnp.int32

    def step(win_code, win_args, res_win, heap_i, heap_f, const_i, const_f,
             scalars):
        cen = scalars[0]
        lo = scalars[1]
        active_n = scalars[2]
        next_free = scalars[3]
        seed = scalars[4]

        iota = jnp.arange(W, dtype=i32)
        lanes = lo + iota
        in_range = iota < active_n
        epoch, tid, valid = decode_code(win_code, T)
        live = in_range & valid & (epoch == cen)

        env = Env(
            res_win=res_win, heap_i=heap_i, heap_f=heap_f,
            const_i=const_i, const_f=const_f,
            cen=cen, lo=lo, active=active_n, next_free=next_free,
            seed=seed, lanes=lanes, W=W, N=N,
        )

        zero_slots = jnp.zeros((W, K), i32)
        masks = [live & (tid == tt.tid) for tt in prog.task_types]

        # ---- phase A: fork counts (bodies called with dummy child slots;
        # XLA CSEs the recomputation against phase B) -------------------
        fork_count = jnp.zeros((W,), i32)
        for tt, m in zip(prog.task_types, masks):
            if tt.max_forks == 0:
                continue
            eff = tt.fn(env, win_args, m, zero_slots)
            if eff.fork_count is not None:
                fork_count = jnp.where(m, eff.fork_count, fork_count)

        base, n_forked = exclusive_scan(fork_count)
        child_slots = next_free + base[:, None] + jnp.arange(K, dtype=i32)[None, :]

        # ---- phase B: full effects with real child slots ---------------
        new_code = win_code
        new_args = win_args
        emit_val_out = jnp.zeros((W,), i32)
        emit_msk_out = jnp.zeros((W,), i32)
        emit_count = jnp.zeros((), i32)
        join_any = jnp.zeros((), i32)
        fork_code_out = jnp.zeros((W * K,), i32)
        fork_args_out = jnp.zeros((W * K, A), i32)
        map_count = jnp.zeros((W,), i32)
        map_args_acc = jnp.zeros((W, Km, Am), i32)

        heap_scatters_i = []
        heap_scatters_f = []
        for tt, m in zip(prog.task_types, masks):
            eff: Effects = tt.fn(env, win_args, m, child_slots)

            # whole-heap returns (bodies that loop, e.g. the naive
            # serial merge): threaded type-by-type; the body is
            # responsible for merging its own lanes' writes.
            if eff.heap_i is not None:
                heap_i = jnp.where(m.any(), eff.heap_i, heap_i)
                env.heap_i = heap_i
            if eff.heap_f is not None:
                heap_f = jnp.where(m.any(), eff.heap_f, heap_f)
                env.heap_f = heap_f

            # heap scatters: collected now (bodies saw the pre-epoch
            # heap), applied after all types ran.
            for (idx, val, smask, op) in eff.heap_i_scatter:
                heap_scatters_i.append((idx, val, m & smask, op))
            for (idx, val, smask, op) in eff.heap_f_scatter:
                heap_scatters_f.append((idx, val, m & smask, op))

            # forks -> compact output at positions base[i] + k
            if eff.fork_count is not None:
                # pad this type's (W, Kt) fork arrays up to program-wide K
                ft, fa = eff.fork_type, eff.fork_args
                kt = ft.shape[1]
                if kt < K:
                    ft = jnp.pad(ft, ((0, 0), (0, K - kt)))
                    fa = jnp.pad(fa, ((0, 0), (0, K - kt), (0, 0)))
                fc = jnp.where(m, eff.fork_count, 0)
                k_iota = jnp.arange(K, dtype=i32)[None, :]
                pos = base[:, None] + k_iota  # (W,K)
                fvalid = m[:, None] & (k_iota < fc[:, None])
                pos = jnp.where(fvalid, pos, W * K)  # drop
                fcode = (cen + 1) * T + ft  # (W,K)
                fork_code_out = fork_code_out.at[pos.reshape(-1)].set(
                    fcode.reshape(-1), mode="drop")
                fork_args_out = fork_args_out.at[pos.reshape(-1)].set(
                    fa.reshape(W * K, A), mode="drop")

            # join -> replace own entry, same epoch number
            if eff.join_mask is not None:
                jm = m & eff.join_mask
                jcode = cen * T + eff.join_type
                new_code = jnp.where(jm, jcode, new_code)
                new_args = jnp.where(jm[:, None], eff.join_args, new_args)
                join_any = join_any | jm.any().astype(i32)
                # lanes of this type that did NOT join are done: invalidate
                done = m & ~eff.join_mask
            else:
                done = m
            new_code = jnp.where(done, 0, new_code)

            # emit -> compact window outputs (the coordinator writes
            # them into its host-side res array)
            if eff.emit_mask is not None:
                em = m & eff.emit_mask
                emit_val_out = jnp.where(em, eff.emit_val, emit_val_out)
                emit_msk_out = emit_msk_out | em.astype(i32)
                emit_count = emit_count + em.sum().astype(i32)

            # map descriptors
            if eff.map_count is not None:
                map_count = jnp.where(m, eff.map_count, map_count)
                map_args_acc = jnp.where(
                    m[:, None, None], eff.map_args, map_args_acc)

        # apply heap scatters (epoch-end visibility, out-of-range drops)
        def apply(arr, scatters, size):
            for (idx, val, smask, op) in scatters:
                safe = jnp.where(smask, idx, size)
                upd = getattr(arr.at[safe], "set" if op == "set" else op)
                arr = upd(val, mode="drop")
            return arr

        heap_i = apply(heap_i, heap_scatters_i, io.Hi)
        heap_f = apply(heap_f, heap_scatters_f, io.Hf)

        # compact map queue (scan over map counts)
        mbase, n_mapped = exclusive_scan(map_count)
        km_iota = jnp.arange(Km, dtype=i32)[None, :]
        mpos = mbase[:, None] + km_iota
        mvalid = km_iota < map_count[:, None]
        mpos = jnp.where(mvalid, mpos, W * Km)
        map_out = jnp.zeros((W * Km, Am), i32).at[mpos.reshape(-1)].set(
            map_args_acc.reshape(W * Km, Am), mode="drop")

        map_any = (n_mapped > 0).astype(i32)
        flags = jnp.stack([
            n_forked, join_any, map_any, n_mapped, emit_count,
            live.sum().astype(i32),
            jnp.zeros((), i32), jnp.zeros((), i32),
        ])

        outs = [new_code, new_args, emit_val_out, emit_msk_out,
                heap_i, heap_f, fork_code_out, fork_args_out]
        if prog.Km > 0:
            outs.append(map_out)
        outs.append(flags)
        return tuple(outs)

    return step


def output_names(prog: Program) -> List[str]:
    names = ["win_code", "win_args", "emit_val", "emit_msk", "heap_i",
             "heap_f", "fork_code", "fork_args"]
    if prog.Km > 0:
        names.append("map_out")
    names.append("flags")
    return names
