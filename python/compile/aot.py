"""AOT lowering: every (app x window-bucket x size-class) epoch-step
computation -> HLO text + a JSON manifest for the Rust coordinator.

HLO *text* (not serialized proto) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` rust crate) rejects; the text parser reassigns
ids and round-trips cleanly.

Build-time only. `make artifacts` runs this; the Rust binary then never
touches Python.

Usage:
  python -m compile.aot --out-dir ../artifacts [--app fib] [--force]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .apps import APP_NAMES, load_app
from .treeslang.core import Program
from .treeslang.epoch import EpochIO, make_epoch_step


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_epoch(prog: Program, io: EpochIO) -> str:
    step = make_epoch_step(prog, io)
    specs = io.input_specs(prog)
    # NB: donation (input_output_alias) was tried here and reverted —
    # it survives the HLO-text round trip but measured ~10% SLOWER on
    # this PJRT CPU build (defensive copies + sync; EXPERIMENTS.md §Perf).
    return to_hlo_text(jax.jit(step, keep_unused=True).lower(*specs))


def lower_map(prog: Program, io: EpochIO, Wm: int) -> str:
    Am = max(prog.map_args, 1)
    i32, f32 = jnp.int32, jnp.float32
    S = jax.ShapeDtypeStruct

    def mstep(map_args, heap_i, heap_f, const_i, const_f, scalars):
        nm = scalars[0]
        mask = jnp.arange(Wm, dtype=i32) < nm
        hi2, hf2 = prog.map_fn(
            dict(heap_i=heap_i, heap_f=heap_f,
                 const_i=const_i, const_f=const_f),
            map_args, mask)
        return hi2, hf2

    specs = (
        S((Wm, Am), i32), S((io.Hi,), i32), S((io.Hf,), f32),
        S((io.Ci,), i32), S((io.Cf,), f32), S((8,), i32),
    )
    return to_hlo_text(jax.jit(mstep, keep_unused=True).lower(*specs))


IO_KEYS = ("N", "Hi", "Hf", "Ci", "Cf", "R")


def io_for(sz: dict, W: int) -> EpochIO:
    """Build an EpochIO from a class dict (which may carry extra app
    keys like VMAX/EMAX that only the app layout cares about)."""
    return EpochIO(W=W, **{k: sz[k] for k in IO_KEYS if k in sz})


def build_app(name: str, out_dir: str, force: bool) -> dict:
    mod = load_app(name)
    # apps whose programs depend on class layout expose program_for_class
    per_class = getattr(mod, "program_for_class", None)
    prog: Program = mod.program() if per_class is None else None
    classes = mod.CLASSES
    buckets = mod.BUCKETS
    probe = prog if prog is not None else per_class(next(iter(classes.values())))
    map_buckets = getattr(mod, "MAP_BUCKETS", [4096] if probe.map_fn else [])

    entry = {
        "T": probe.T,
        "A": probe.num_args,
        "K": probe.K,
        "Km": probe.Km,
        "Am": probe.map_args,
        "G": probe.gather_width,
        "task_types": [tt.name for tt in probe.task_types],
        "max_forks": [tt.max_forks for tt in probe.task_types],
        "artifacts": [],
        "map_artifacts": [],
        "classes": {k: dict(v) for k, v in classes.items()},
    }

    for cls, sz in classes.items():
        cprog = prog if prog is not None else per_class(sz)
        for W in buckets:
            io = io_for(sz, W)
            fname = f"{name}__w{W}__{cls}.hlo.txt"
            path = os.path.join(out_dir, fname)
            if force or not os.path.exists(path):
                text = lower_epoch(cprog, io)
                with open(path, "w") as f:
                    f.write(text)
                print(f"  wrote {fname} ({len(text)//1024} KiB)")
            entry["artifacts"].append(
                dict(file=fname, W=W, cls=cls, R=io.R, **{
                    k: v for k, v in sz.items() if k != "R"}))
        for Wm in map_buckets:
            io = io_for(sz, 1)
            fname = f"{name}__map{Wm}__{cls}.hlo.txt"
            path = os.path.join(out_dir, fname)
            if force or not os.path.exists(path):
                text = lower_map(cprog, io, Wm)
                with open(path, "w") as f:
                    f.write(text)
                print(f"  wrote {fname} ({len(text)//1024} KiB)")
            entry["map_artifacts"].append(
                dict(file=fname, Wm=Wm, cls=cls, R=io.R, **{
                    k: v for k, v in sz.items() if k != "R"}))
    return entry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--app", action="append", default=None,
                    help="limit to specific app(s)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    from .baselines import BASELINE_NAMES, load_baseline

    names = args.app or (APP_NAMES + BASELINE_NAMES)
    manifest = {"version": 1, "apps": {}}
    for name in names:
        print(f"[aot] {name}")
        try:
            if name in BASELINE_NAMES:
                manifest["apps"][name] = load_baseline(name).build(
                    name, args.out_dir, args.force)
            else:
                manifest["apps"][name] = build_app(name, args.out_dir, args.force)
        except ModuleNotFoundError as e:
            print(f"  skipped ({e})")
    # merge with any existing manifest so per-app rebuilds keep others
    mpath = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(mpath) and args.app:
        with open(mpath) as f:
            old = json.load(f)
        old["apps"].update(manifest["apps"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] manifest: {mpath} ({len(manifest['apps'])} apps)")


if __name__ == "__main__":
    main()
