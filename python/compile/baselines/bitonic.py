"""Native bitonic sort — the hand-coded comparator of Fig 9.

A classic data-parallel bitonic network over a power-of-two array: one
artifact executes the full log^2(n) stage schedule in a single fused
computation (the Rust driver launches it once per sort — the strongest
native baseline configuration).

Artifact signature (per size class):
  inputs : data f32[NMAX], scalars i32[8] ([0] = n, power of two)
  outputs: data' f32[NMAX]

Elements at index >= n must be pre-set to +inf by the driver.
"""

import os

import jax
import jax.numpy as jnp

from ..kernels.bitonic import bitonic_sort

CLASSES = {
    "S": dict(NMAX=1 << 10),
    "M": dict(NMAX=1 << 16),
    "L": dict(NMAX=1 << 20),
}


def lower(NMAX: int) -> str:
    from ..aot import to_hlo_text

    def step(data, scalars):
        _ = scalars
        return (bitonic_sort(data),)

    S = jax.ShapeDtypeStruct
    specs = (S((NMAX,), jnp.float32), S((8,), jnp.int32))
    return to_hlo_text(jax.jit(step, keep_unused=True).lower(*specs))


def build(name: str, out_dir: str, force: bool) -> dict:
    entry = {
        "T": 0, "A": 0, "K": 0, "Km": 0, "Am": 0,
        "task_types": [], "max_forks": [],
        "artifacts": [], "map_artifacts": [], "classes": {},
    }
    for cls, sz in CLASSES.items():
        NMAX = sz["NMAX"]
        entry["classes"][cls] = dict(NMAX=NMAX)
        fname = f"{name}__{cls}.hlo.txt"
        path = os.path.join(out_dir, fname)
        if force or not os.path.exists(path):
            text = lower(NMAX)
            with open(path, "w") as f:
                f.write(text)
            print(f"  wrote {fname} ({len(text)//1024} KiB)")
        entry["artifacts"].append(dict(
            file=fname, W=0, cls=cls, N=0, R=0,
            Hi=1, Hf=NMAX, Ci=1, Cf=1, NMAX=NMAX))
    return entry
