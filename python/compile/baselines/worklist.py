"""Native worklist BFS/SSSP — the hand-coded comparator of Fig 7/8.

The Lonestar benchmarks keep input/output worklists and relax the
frontier each kernel; the host transfers one int per iteration to decide
whether another relaxation kernel is needed. This is exactly that loop,
minus the TREES generality layer: one fused relaxation step per
iteration (Pallas edge-relax kernel + scatter-min + frontier rebuild),
with the Rust driver reading back the `changed` flag.

Artifact signature (per size class):
  inputs : dist i32[VMAX], frontier i32[VMAX], const_i i32[Ci], scalars i32[8]
  outputs: dist' i32[VMAX], frontier' i32[VMAX], changed i32

const_i layout:
  [0]=V [1]=E [2]=src [3]=reserved
  [4 ..]                 esrc  (EMAX)   edge source vertex
  [4+EMAX ..]            ecol  (EMAX)   edge target vertex
  [4+2*EMAX ..]          ew    (EMAX)   weight (sssp only; bfs uses 1)
"""

import json
import os

import jax
import jax.numpy as jnp

from ..kernels.relax import INF, relax_proposals

i32 = jnp.int32

CLASSES = {
    "S": dict(VMAX=256, EMAX=4096),
    "M": dict(VMAX=4096, EMAX=16384),
    "L": dict(VMAX=8192, EMAX=65536),
    "XL": dict(VMAX=16384, EMAX=262144),
}


def make_step(weighted: bool, VMAX: int, EMAX: int):
    ESRC = 4
    ECOL = ESRC + EMAX
    EW = ECOL + EMAX

    def step(dist, frontier, const_i, scalars):
        esrc = const_i[ESRC:ESRC + EMAX]
        ecol = const_i[ECOL:ECOL + EMAX]
        ew = (
            const_i[EW:EW + EMAX]
            if weighted
            else jnp.ones((EMAX,), i32)
        )
        nd = relax_proposals(dist, esrc, ew, frontier)
        dist2 = dist.at[ecol].min(nd)  # INF proposals are no-ops
        frontier2 = (dist2 < dist).astype(i32)
        changed = frontier2.sum().astype(i32)
        _ = scalars
        return dist2, frontier2, changed

    return step


def lower(weighted: bool, VMAX: int, EMAX: int) -> str:
    from ..aot import to_hlo_text

    ci = 4 + (3 if weighted else 2) * EMAX
    S = jax.ShapeDtypeStruct
    specs = (
        S((VMAX,), i32),
        S((VMAX,), i32),
        S((ci,), i32),
        S((8,), i32),
    )
    step = make_step(weighted, VMAX, EMAX)
    return to_hlo_text(jax.jit(step, keep_unused=True).lower(*specs))


def build(name: str, out_dir: str, force: bool) -> dict:
    weighted = name == "native_sssp"
    entry = {
        "T": 0, "A": 0, "K": 0, "Km": 0, "Am": 0,
        "task_types": [], "max_forks": [],
        "artifacts": [], "map_artifacts": [],
        "classes": {},
    }
    for cls, sz in CLASSES.items():
        VMAX, EMAX = sz["VMAX"], sz["EMAX"]
        ci = 4 + (3 if weighted else 2) * EMAX
        entry["classes"][cls] = dict(VMAX=VMAX, EMAX=EMAX, Ci=ci)
        fname = f"{name}__{cls}.hlo.txt"
        path = os.path.join(out_dir, fname)
        if force or not os.path.exists(path):
            text = lower(weighted, VMAX, EMAX)
            with open(path, "w") as f:
                f.write(text)
            print(f"  wrote {fname} ({len(text)//1024} KiB)")
        entry["artifacts"].append(dict(
            file=fname, W=0, cls=cls, N=0, R=0,
            Hi=VMAX, Hf=1, Ci=ci, Cf=1, VMAX=VMAX, EMAX=EMAX))
    return entry
