"""Hand-coded native baselines (no TREES machinery): the comparators of
Fig 7/8 (worklist BFS/SSSP) and Fig 9 (bitonic sort). Each module
exposes ``build(out_dir, force) -> manifest entry``; aot.py includes
them under pseudo-app names.
"""

BASELINE_NAMES = ["native_bfs", "native_sssp", "native_bitonic"]


def load_baseline(name: str):
    from importlib import import_module
    mod = {
        "native_bfs": "worklist",
        "native_sssp": "worklist",
        "native_bitonic": "bitonic",
    }[name]
    return import_module(f"compile.baselines.{mod}")
