"""Shared BFS/SSSP program builder (Fig 7/8).

Data-driven relaxation in TVM form (the task-parallel analogue of the
Lonestar worklist algorithms the paper compares against):

  visit(u, d):          if dist[u] != d: die            # stale visit
                        else fork expand(u, rp[u], rp[u+1], d)
  expand(u, lo, hi, d): if dist[u] != d: die            # stale subtree
                        if hi-lo > 2: fork 2 half-range expands
                        else: for each edge e in [lo,hi):
                                v = col[e]; nd = d + w(e)
                                if nd < dist[v]:
                                    dist[v] <- min (epoch-end merge)
                                    fork visit(v, nd)

Unlike the paper's atomic worklist push, fork slots come from the
prefix-sum allocator (work-together Tenet 2). Duplicate visits (several
same-epoch relaxations of one vertex with equal distance) are tolerated:
the dist gate kills all but the ones carrying the current best distance,
matching Lonestar's own duplicate-work behaviour.

Duplicate-visit dedup ("claim"): with many equal-length paths (grids!),
several same-epoch relaxations of one vertex would each fork a visit and
each expand the vertex's adjacency — exponential duplication. Each
improving relax therefore min-scatters

    claim[v] = nd * 2^16 + (window_lane & 0xffff)

and only the winning lane forks the visit. Packing distance in the high
bits makes staleness harmless: an old claim always carries nd_old >=
dist[v] > nd, so a strictly-improving relax always beats it. (Requires
distances < 2^15 — asserted by the Rust workload builder.) This is the
work-together analogue of Lonestar's atomic test-and-set on the output
worklist. The scalar interpreter oracle skips dedup (duplicates are
semantically harmless), so differential tests compare distances, not
task counts.

const_i layout (static per size class):
  [0]=V [1]=E [2]=src [3]=reserved
  [4          .. 4+VMAX]        row_ptr  (VMAX+1 entries)
  [4+VMAX+1   .. +EMAX]         col
  [.. +EMAX]                    weights  (sssp only)
heap_i: dist[VMAX] ++ claim[VMAX]
"""

import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects

INF = 1 << 30
A = 4
i32 = jnp.int32

T_VISIT = 1
T_EXPAND = 2


def make_graph_program(name: str, weighted: bool, VMAX: int, EMAX: int) -> Program:
    RP = 4
    COL = RP + VMAX + 1
    WOFF = COL + EMAX

    def visit_fn(env, args, mask, child_slots):
        W = env.W
        u = jnp.clip(args[:, 0], 0, VMAX - 1)
        d = args[:, 1]
        dist_u = env.heap_i[u]
        ok = mask & (dist_u == d)
        rp0 = env.const_i[RP + u]
        rp1 = env.const_i[RP + u + 1]
        fork = ok & (rp1 > rp0)

        fa = jnp.zeros((W, 1, A), i32)
        fa = fa.at[:, 0, 0].set(args[:, 0])
        fa = fa.at[:, 0, 1].set(rp0)
        fa = fa.at[:, 0, 2].set(rp1)
        fa = fa.at[:, 0, 3].set(d)
        return Effects(
            fork_count=fork.astype(i32),
            fork_type=jnp.full((W, 1), T_EXPAND, i32),
            fork_args=fa,
        )

    def expand_fn(env, args, mask, child_slots):
        W = env.W
        u = jnp.clip(args[:, 0], 0, VMAX - 1)
        lo, hi, d = args[:, 1], args[:, 2], args[:, 3]
        dist_u = env.heap_i[u]
        ok = mask & (dist_u == d)
        small = (hi - lo) <= 2
        mid = (lo + hi) // 2

        # --- leaf: relax up to 2 edges -------------------------------
        e0 = jnp.clip(lo, 0, EMAX - 1)
        e1 = jnp.clip(lo + 1, 0, EMAX - 1)
        has1 = lo + 1 < hi
        v0 = jnp.clip(env.const_i[COL + e0], 0, VMAX - 1)
        v1 = jnp.clip(env.const_i[COL + e1], 0, VMAX - 1)
        if weighted:
            w0 = env.const_i[WOFF + e0]
            w1 = env.const_i[WOFF + e1]
        else:
            w0 = jnp.ones((W,), i32)
            w1 = jnp.ones((W,), i32)
        nd0 = d + w0
        nd1 = d + w1
        leaf = ok & small
        imp0 = leaf & (nd0 < env.heap_i[v0])
        imp1 = leaf & has1 & (nd1 < env.heap_i[v1])

        # claim dedup: winner of the epoch-collective min forks the visit
        lane16 = jnp.arange(W, dtype=i32) & 0xFFFF
        cv0 = nd0 * 65536 + lane16
        cv1 = nd1 * 65536 + lane16
        c_idx0 = jnp.where(imp0, VMAX + v0, 2 * VMAX)
        c_idx1 = jnp.where(imp1, VMAX + v1, 2 * VMAX)
        claim2 = env.heap_i.at[c_idx0].min(cv0, mode="drop")
        claim2 = claim2.at[c_idx1].min(cv1, mode="drop")
        win0 = imp0 & (claim2[VMAX + v0] == cv0)
        win1 = imp1 & (claim2[VMAX + v1] == cv1)

        # lane-local compaction: if only edge 1 wins it takes slot 0
        first_v = jnp.where(win0, v0, v1)
        first_nd = jnp.where(win0, nd0, nd1)

        # --- assemble forks ------------------------------------------
        n_leaf = win0.astype(i32) + win1.astype(i32)
        fork_count = jnp.where(ok, jnp.where(small, n_leaf, 2), 0)
        ftype = jnp.where(
            small[:, None], T_VISIT, T_EXPAND
        ) * jnp.ones((W, 2), i32)

        fa = jnp.zeros((W, 2, A), i32)
        # slot 0: visit(first_v, first_nd)  |  expand(u, lo, mid, d)
        fa = fa.at[:, 0, 0].set(jnp.where(small, first_v, args[:, 0]))
        fa = fa.at[:, 0, 1].set(jnp.where(small, first_nd, lo))
        fa = fa.at[:, 0, 2].set(jnp.where(small, 0, mid))
        fa = fa.at[:, 0, 3].set(jnp.where(small, 0, d))
        # slot 1: visit(v1, nd1)            |  expand(u, mid, hi, d)
        fa = fa.at[:, 1, 0].set(jnp.where(small, v1, args[:, 0]))
        fa = fa.at[:, 1, 1].set(jnp.where(small, nd1, mid))
        fa = fa.at[:, 1, 2].set(jnp.where(small, 0, hi))
        fa = fa.at[:, 1, 3].set(jnp.where(small, 0, d))

        return Effects(
            fork_count=fork_count,
            fork_type=ftype,
            fork_args=fa,
            heap_i_scatter=[
                (v0, nd0, imp0, "min"),
                (v1, nd1, imp1, "min"),
                (VMAX + v0, cv0, imp0, "min"),
                (VMAX + v1, cv1, imp1, "min"),
            ],
        )

    return Program(
        name=name,
        task_types=[
            TaskType("visit", visit_fn, max_forks=1),
            TaskType("expand", expand_fn, max_forks=2),
        ],
        num_args=A,
    )


def class_dict(VMAX: int, EMAX: int, N: int, weighted: bool) -> dict:
    ci = 4 + VMAX + 1 + EMAX + (EMAX if weighted else 0)
    # heap: dist[VMAX] ++ claim[VMAX]
    return dict(N=N, Hi=2 * VMAX, Hf=1, Ci=ci, Cf=1, R=1, VMAX=VMAX, EMAX=EMAX)
