"""Shared mergesort program builder (Fig 9): naive (serial-merge task)
and sophisticated (data-parallel map merge) variants.

  sort(lo, hi):  hi-lo <= G -> leaf: sorting-network sort in place
                 else fork sort(lo,mid), sort(mid,hi); join merge(lo,mid,hi)
  merge(lo, mid, hi):
     naive: two-pointer serial merge inside the task (a fori_loop over
            the whole output — the "abysmal" single-work-item merge the
            paper uses to motivate map)
     map:   emit one map descriptor; the merge-path kernel merges the
            whole level data-parallel after the epoch

Buffers ping-pong by level: heap_f = bufA[NMAX] ++ bufB[NMAX]. Leaves
(level 0) sort in place in A; the merge at level L (block size G*2^L)
reads parity (L-1)%2 and writes parity L%2.
"""

import jax
import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects
from ..kernels.merge import merge_level

A = 4
G = 4  # leaf run length
i32 = jnp.int32
f32 = jnp.float32

T_SORT = 1
T_MERGE = 2


def _level_of(size):
    """Merge level L for block size `size` = G * 2^L (exact for pow2)."""
    return jnp.round(jnp.log2(size.astype(f32) / G)).astype(i32)


def _offsets(size, NMAX):
    lvl = _level_of(size)
    src = ((lvl - 1) % 2) * NMAX
    dst = (lvl % 2) * NMAX
    return src, dst


def make_msort_program(name: str, use_map: bool, NMAX: int) -> Program:
    def sort_fn(env, args, mask, child_slots):
        W = env.W
        lo, hi = args[:, 0], args[:, 1]
        size = hi - lo
        leaf = size <= G
        mid = (lo + hi) // 2

        # leaf: gather G elements from buffer A, sort, scatter back
        gidx = jnp.clip(lo[:, None] + jnp.arange(G, dtype=i32)[None, :],
                        0, NMAX - 1)  # (W,G)
        vals = env.heap_f[gidx]
        svals = jnp.sort(vals, axis=1)
        scat = []
        for k in range(G):
            scat.append((gidx[:, k], svals[:, k], mask & leaf, "set"))

        fa = jnp.zeros((W, 2, A), i32)
        fa = fa.at[:, 0, 0].set(lo)
        fa = fa.at[:, 0, 1].set(mid)
        fa = fa.at[:, 1, 0].set(mid)
        fa = fa.at[:, 1, 1].set(hi)
        ja = jnp.zeros((W, A), i32)
        ja = ja.at[:, 0].set(lo)
        ja = ja.at[:, 1].set(mid)
        ja = ja.at[:, 2].set(hi)
        return Effects(
            fork_count=jnp.where(mask & ~leaf, 2, 0).astype(i32),
            fork_type=jnp.full((W, 2), T_SORT, i32),
            fork_args=fa,
            join_mask=~leaf,
            join_type=jnp.full((W,), T_MERGE, i32),
            join_args=ja,
            heap_f_scatter=scat,
        )

    def merge_map_fn(env, args, mask, child_slots):
        W = env.W
        ma = jnp.zeros((W, 1, A), i32)
        ma = ma.at[:, 0, 0].set(args[:, 0])
        ma = ma.at[:, 0, 1].set(args[:, 1])
        ma = ma.at[:, 0, 2].set(args[:, 2])
        return Effects(
            map_count=mask.astype(i32),
            map_args=ma,
        )

    def merge_naive_fn(env, args, mask, child_slots):
        lo, mid, hi = args[:, 0], args[:, 1], args[:, 2]
        size = hi - lo
        src, dst = _offsets(size, NMAX)

        def step(j, carry):
            heap, ia, ib = carry
            a = heap[jnp.clip(src + ia, 0, 2 * NMAX - 1)]
            b = heap[jnp.clip(src + ib, 0, 2 * NMAX - 1)]
            take_a = (ia < mid) & ((ib >= hi) | (a <= b))
            v = jnp.where(take_a, a, b)
            valid = mask & (j < size)
            idx = jnp.where(valid, dst + lo + j, 2 * NMAX)
            heap = heap.at[idx].set(v, mode="drop")
            ia = ia + (take_a & valid).astype(i32)
            ib = ib + (~take_a & valid).astype(i32)
            return heap, ia, ib

        heap, _, _ = jax.lax.fori_loop(
            0, NMAX, step, (env.heap_f, lo, mid))
        return Effects(heap_f=heap)

    def map_fn(envd, map_args, mask):
        heap_f = envd["heap_f"]
        lo0, mid0, hi0 = map_args[0, 0], map_args[0, 1], map_args[0, 2]
        size = hi0 - lo0
        nm = mask.sum().astype(i32)
        total = nm * size
        src, dst = _offsets(size, NMAX)
        merged = merge_level(heap_f, size, total, src, nmax=NMAX)
        # write merged[0:total] into the dst half
        iota = jnp.arange(NMAX, dtype=i32)
        dst_half = jax.lax.dynamic_slice(heap_f, (dst,), (NMAX,))
        new_half = jnp.where(iota < total, merged, dst_half)
        heap_f = jax.lax.dynamic_update_slice(heap_f, new_half, (dst,))
        return envd["heap_i"], heap_f

    merge_fn = merge_map_fn if use_map else merge_naive_fn
    return Program(
        name=name,
        task_types=[
            TaskType("sort", sort_fn, max_forks=2),
            TaskType("merge", merge_fn, max_forks=0,
                     max_maps=1 if use_map else 0),
        ],
        num_args=A,
        map_args=A if use_map else 0,
        map_fn=map_fn if use_map else None,
    )


def class_dict(NMAX: int, N: int) -> dict:
    return dict(N=N, Hi=1, Hf=2 * NMAX, Ci=1, Cf=1, R=1, NMAX=NMAX)
