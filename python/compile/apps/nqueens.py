"""N-Queens solution counting (§6.5 programmability app).

  nq(row, cols, d1, d2): row == n -> emit 1 (a solution)
      else fork nq(row+1, ...) for each non-attacked column;
           join sumk(first_child_slot, count)
  sumk(first, count): emit sum(res[first .. first+count))

Bitmask pruning (cols/diagonals packed in i32). Forked children land in
a CONTIGUOUS slot run (prefix-sum allocation — paper §5.1.2 observation
2), so the join only needs the first slot and the count.

const_i: [n]. Supports n <= 12 (K = 12).
"""

import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects

A = 4
NQ_MAX = 12
i32 = jnp.int32

T_NQ = 1
T_SUMK = 2


def _nq_fn(env, args, mask, child_slots):
    W = env.W
    n = env.const_i[0]
    row, cols, d1, d2 = args[:, 0], args[:, 1], args[:, 2], args[:, 3]
    done = row >= n
    attacked = cols | d1 | d2
    fa = jnp.zeros((W, NQ_MAX, A), i32)
    pos = jnp.zeros((W,), i32)
    for c in range(NQ_MAX):
        bit = 1 << c
        ok = mask & ~done & (c < n) & ((attacked & bit) == 0)
        lanes = jnp.arange(W)
        p = jnp.where(ok, pos, NQ_MAX - 1)  # parked writes get overwritten
        fa = fa.at[(lanes, p, jnp.full((W,), 0))].set(
            jnp.where(ok, row + 1, fa[(lanes, p, jnp.full((W,), 0))]))
        fa = fa.at[(lanes, p, jnp.full((W,), 1))].set(
            jnp.where(ok, cols | bit, fa[(lanes, p, jnp.full((W,), 1))]))
        fa = fa.at[(lanes, p, jnp.full((W,), 2))].set(
            jnp.where(ok, ((d1 | bit) << 1) & 0xFFF,
                      fa[(lanes, p, jnp.full((W,), 2))]))
        fa = fa.at[(lanes, p, jnp.full((W,), 3))].set(
            jnp.where(ok, (d2 | bit) >> 1, fa[(lanes, p, jnp.full((W,), 3))]))
        pos = pos + ok.astype(i32)

    fork_count = jnp.where(mask & ~done, pos, 0)
    ja = jnp.zeros((W, A), i32)
    ja = ja.at[:, 0].set(child_slots[:, 0])
    ja = ja.at[:, 1].set(fork_count)
    has_kids = fork_count > 0
    return Effects(
        fork_count=fork_count,
        fork_type=jnp.full((W, NQ_MAX), T_NQ, i32),
        fork_args=fa,
        join_mask=~done & has_kids,
        join_type=jnp.full((W,), T_SUMK, i32),
        join_args=ja,
        # dead ends (no kids, not done) emit 0; completed rows emit 1
        emit_mask=done | (~done & ~has_kids),
        emit_val=done.astype(i32),
    )


def _sumk_fn(env, args, mask, child_slots):
    W = env.W
    count = args[:, 1]
    total = jnp.zeros((W,), i32)
    for k in range(NQ_MAX):
        total = total + jnp.where(k < count, env.res_win[:, k], 0)
    return Effects(emit_mask=jnp.ones_like(mask), emit_val=total)


def _gather(tid, args, res):
    if tid == T_SUMK:
        first, count = args[0], args[1]
        return [res[first + k] if k < count else 0 for k in range(NQ_MAX)]
    return [0] * NQ_MAX


def program() -> Program:
    return Program(
        name="nqueens",
        task_types=[
            TaskType("nq", _nq_fn, max_forks=NQ_MAX),
            TaskType("sumk", _sumk_fn),
        ],
        num_args=A,
        gather_width=NQ_MAX,
        gather=_gather,
    )


CLASSES = {
    "S": dict(N=1 << 16, Hi=1, Hf=1, Ci=1, Cf=1, R=1 << 16),
    "M": dict(N=1 << 21, Hi=1, Hf=1, Ci=1, Cf=1, R=1 << 21),
}
BUCKETS = [256, 1024, 4096]
