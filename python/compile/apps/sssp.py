"""SSSP (Fig 8): weighted data-driven relaxation. See _graph.py."""

from ._graph import class_dict, make_graph_program


def program_for_class(sz: dict):
    return make_graph_program("sssp", True, sz["VMAX"], sz["EMAX"])


CLASSES = {
    "S": class_dict(VMAX=256, EMAX=4096, N=1 << 14, weighted=True),
    "M": class_dict(VMAX=16384, EMAX=262144, N=1 << 20, weighted=True),
}
BUCKETS = [256, 1024, 4096]
