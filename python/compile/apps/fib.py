"""Naive Fibonacci — the paper's worst-case stress test (Fig 5).

fib(n):  n < 2 -> emit n
         else  -> fork fib(n-1); fork fib(n-2); join sum2(c0, c1)
sum2(a, b): emit res[a] + res[b]

Maximizes runtime overhead per unit of work: each task does O(1)
arithmetic, so Fig 5 measures the runtime itself.

args layout: fib:  [n, -, -, -]
             sum2: [slot_of_child0, slot_of_child1, -, -]
"""

import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects

A = 4
i32 = jnp.int32


def _fib_fn(env, args, mask, child_slots):
    W = env.W
    n = args[:, 0]
    leaf = n < 2

    fork_count = jnp.where(leaf, 0, 2).astype(i32)
    fork_type = jnp.full((W, 2), 1, i32)  # both forks are fib
    fa = jnp.zeros((W, 2, A), i32)
    fa = fa.at[:, 0, 0].set(n - 1)
    fa = fa.at[:, 1, 0].set(n - 2)

    ja = jnp.zeros((W, A), i32)
    ja = ja.at[:, 0].set(child_slots[:, 0])
    ja = ja.at[:, 1].set(child_slots[:, 1])

    return Effects(
        fork_count=fork_count,
        fork_type=fork_type,
        fork_args=fa,
        join_mask=~leaf,
        join_type=jnp.full((W,), 2, i32),  # sum2
        join_args=ja,
        emit_mask=leaf,
        emit_val=n,
    )


def _sum2_fn(env, args, mask, child_slots):
    a = env.res_win[:, 0]
    b = env.res_win[:, 1]
    return Effects(
        emit_mask=jnp.ones_like(mask),
        emit_val=(a + b).astype(i32),
    )


def _gather(tid, args, res):
    """Host-side res gather: sum2's operands live at its child slots."""
    if tid == 2:
        return [res[args[0]], res[args[1]]]
    return [0, 0]


def program() -> Program:
    return Program(
        name="fib",
        task_types=[
            TaskType("fib", _fib_fn, max_forks=2),
            TaskType("sum2", _sum2_fn),
        ],
        num_args=A,
        gather_width=2,
        gather=_gather,
    )


# AOT size classes: N must hold the peak TV size (~2*fib(n+1) entries).
# class S covers fib<=22, M fib<=28, L fib<=32.
CLASSES = {
    "S": dict(N=1 << 16, Hi=1, Hf=1, Ci=1, Cf=1),
    "M": dict(N=1 << 19, Hi=1, Hf=1, Ci=1, Cf=1),
    "L": dict(N=1 << 21, Hi=1, Hf=1, Ci=1, Cf=1),
}
BUCKETS = [256, 1024, 4096]

# Rust-side workload: initial task = fib(n) with args [n,0,0,0].
