"""TREES applications (L2): one module per app, each exporting
``program()`` returning a `treeslang.Program` plus the AOT size-class
table consumed by `aot.py`.

Registry order is stable; the Rust side mirrors task-type ids.
"""

from importlib import import_module

APP_NAMES = [
    "fib",
    "tree",
    "bfs",
    "sssp",
    "fft",
    "mergesort",
    "msort_map",
    "nqueens",
    "matmul",
    "tsp",
    "annealing",
]


def load_app(name: str):
    return import_module(f"compile.apps.{name}")


def all_apps():
    return [(n, load_app(n)) for n in APP_NAMES]
