"""Naive TREES mergesort (Fig 9): serial merge inside a single task —
the configuration the paper shows performing "abysmally"."""

from ._msort import class_dict, make_msort_program


def program_for_class(sz: dict):
    return make_msort_program("mergesort", False, sz["NMAX"])


CLASSES = {
    "S": class_dict(NMAX=1 << 10, N=1 << 12),
    "M": class_dict(NMAX=1 << 14, N=1 << 16),
}
BUCKETS = [256, 1024, 4096]
