"""BFS (Fig 7): unweighted data-driven relaxation. See _graph.py."""

from ._graph import class_dict, make_graph_program


def program_for_class(sz: dict):
    return make_graph_program("bfs", False, sz["VMAX"], sz["EMAX"])


CLASSES = {
    "S": class_dict(VMAX=256, EMAX=4096, N=1 << 14, weighted=False),
    "M": class_dict(VMAX=16384, EMAX=262144, N=1 << 20, weighted=False),
}
BUCKETS = [256, 1024, 4096]
