"""Postorder tree traversal — the paper's own walkthrough example
(Fig 2-4). Doubles as a subtree-size reduction so correctness is
observable:

  postorder(node): leaf -> emit 1
                   else fork postorder(left), postorder(right)
                        join visitAfter(node, c_left, c_right)
  visitAfter(node, c0, c1): stamp heap_i[node] = cen (visit order proof)
                            emit 1 + res[c0] + res[c1]

const_i: [n, reserved x3, left[NMAX], right[NMAX]]  (-1 = absent child)
heap_i:  execution-order stamp per node (postorder => parent stamped later)
"""

import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects

A = 4
i32 = jnp.int32

T_POST = 1
T_VISIT = 2


def make_tree_program(NMAX: int) -> Program:
    LEFT = 4
    RIGHT = LEFT + NMAX

    def post_fn(env, args, mask, child_slots):
        W = env.W
        node = jnp.clip(args[:, 0], 0, NMAX - 1)
        left = env.const_i[LEFT + node]
        right = env.const_i[RIGHT + node]
        has_l = left >= 0
        has_r = right >= 0
        leaf = ~has_l & ~has_r

        # children compact into fork slots 0..count
        first = jnp.where(has_l, left, right)
        fork_count = has_l.astype(i32) + has_r.astype(i32)
        fa = jnp.zeros((W, 2, A), i32)
        fa = fa.at[:, 0, 0].set(first)
        fa = fa.at[:, 1, 0].set(right)

        # join args: node, slot of child 0, slot of child 1 (or -1)
        ja = jnp.zeros((W, A), i32)
        ja = ja.at[:, 0].set(node)
        ja = ja.at[:, 1].set(jnp.where(fork_count >= 1, child_slots[:, 0], -1))
        ja = ja.at[:, 2].set(jnp.where(fork_count >= 2, child_slots[:, 1], -1))
        return Effects(
            fork_count=jnp.where(mask & ~leaf, fork_count, 0),
            fork_type=jnp.full((W, 2), T_POST, i32),
            fork_args=fa,
            join_mask=~leaf,
            join_type=jnp.full((W,), T_VISIT, i32),
            join_args=ja,
            emit_mask=leaf,
            emit_val=jnp.ones((W,), i32),
        )

    def visit_fn(env, args, mask, child_slots):
        node = jnp.clip(args[:, 0], 0, NMAX - 1)
        r0 = env.res_win[:, 0]
        r1 = env.res_win[:, 1]
        return Effects(
            emit_mask=jnp.ones_like(mask),
            emit_val=(1 + r0 + r1).astype(i32),
            heap_i_scatter=[(node, env.seed * jnp.ones_like(node), mask, "set")],
        )

    def gather(tid, args, res):
        if tid == T_VISIT:
            c0, c1 = args[1], args[2]
            return [res[c0] if c0 >= 0 else 0, res[c1] if c1 >= 0 else 0]
        return [0, 0]

    return Program(
        name="tree",
        task_types=[
            TaskType("postorder", post_fn, max_forks=2),
            TaskType("visitAfter", visit_fn),
        ],
        num_args=A,
        gather_width=2,
        gather=gather,
    )


def program_for_class(sz: dict):
    return make_tree_program(sz["NMAX"])


CLASSES = {
    "S": dict(N=1 << 12, Hi=1 << 10, Hf=1, Ci=4 + 2 * (1 << 10), Cf=1,
              NMAX=1 << 10),
    "M": dict(N=1 << 18, Hi=1 << 16, Hf=1, Ci=4 + 2 * (1 << 16), Cf=1,
              NMAX=1 << 16),
}
BUCKETS = [256, 1024, 4096]
