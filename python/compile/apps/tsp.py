"""Exhaustive TSP with branch-and-bound pruning (§6.5 app).

  tour(last, visited, cost, depth):
      prune if cost >= best (heap_i[0], min-merged global bound)
      depth == n -> close the tour: emit cost + d(last, 0); publish bound
      else fork tour(c, ...) per unvisited city c; join mink(first, count)
  mink(first, count): emit min(res[first..first+count))

The global bound is shared through the heap with epoch-end min-merge —
the work-together version of a racy global best (reads may be one epoch
stale; pruning is conservative, never wrong).

const_i: [n, reserved x3, dist matrix n*n (row-major, <= 12x12)]
heap_i:  [0] = best tour cost seen (INF-initialized)
Supports n <= 10 (K = 10). INF emitted for pruned branches.
"""

import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects

A = 4
TSP_MAX = 10
INF = 1 << 28
i32 = jnp.int32

T_TOUR = 1
T_MINK = 2


def make_tsp_program(NC: int) -> Program:
    D = 4  # dist matrix offset in const_i

    def tour_fn(env, args, mask, child_slots):
        W = env.W
        n = env.const_i[0]
        last, visited, cost = args[:, 0], args[:, 1], args[:, 2]
        depth = args[:, 3]
        best = env.heap_i[0]
        pruned = cost >= best
        complete = depth >= n

        back = env.const_i[D + jnp.clip(last * NC + 0, 0, NC * NC - 1) + 0]
        closed = cost + back

        fa = jnp.zeros((W, TSP_MAX, A), i32)
        pos = jnp.zeros((W,), i32)
        lanes = jnp.arange(W)
        for c in range(TSP_MAX):
            step = env.const_i[D + jnp.clip(last * NC + c, 0, NC * NC - 1)]
            ncost = cost + step
            ok = (mask & ~pruned & ~complete & (c < n)
                  & ((visited & (1 << c)) == 0) & (ncost < best))
            p = jnp.where(ok, pos, TSP_MAX - 1)
            for (slot, val) in [(0, jnp.full((W,), c, i32)),
                                (1, visited | (1 << c)),
                                (2, ncost),
                                (3, depth + 1)]:
                cur = fa[(lanes, p, jnp.full((W,), slot))]
                fa = fa.at[(lanes, p, jnp.full((W,), slot))].set(
                    jnp.where(ok, val, cur))
            pos = pos + ok.astype(i32)

        fork_count = pos
        has_kids = fork_count > 0
        ja = jnp.zeros((W, A), i32)
        ja = ja.at[:, 0].set(child_slots[:, 0])
        ja = ja.at[:, 1].set(fork_count)

        emit_complete = mask & ~pruned & complete
        return Effects(
            fork_count=fork_count,
            fork_type=jnp.full((W, TSP_MAX), T_TOUR, i32),
            fork_args=fa,
            join_mask=~pruned & ~complete & has_kids,
            join_type=jnp.full((W,), T_MINK, i32),
            join_args=ja,
            emit_mask=pruned | complete | (~complete & ~has_kids),
            emit_val=jnp.where(emit_complete, closed, INF),
            heap_i_scatter=[
                (jnp.zeros((W,), i32), closed, emit_complete, "min"),
            ],
        )

    def mink_fn(env, args, mask, child_slots):
        W = env.W
        count = args[:, 1]
        best = jnp.full((W,), INF, i32)
        for k in range(TSP_MAX):
            best = jnp.minimum(
                best, jnp.where(k < count, env.res_win[:, k], INF))
        return Effects(emit_mask=jnp.ones_like(mask), emit_val=best)

    def gather(tid, args, res):
        if tid == T_MINK:
            first, count = args[0], args[1]
            return [res[first + k] if k < count else INF
                    for k in range(TSP_MAX)]
        return [INF] * TSP_MAX

    return Program(
        name="tsp",
        task_types=[
            TaskType("tour", tour_fn, max_forks=TSP_MAX),
            TaskType("mink", mink_fn),
        ],
        num_args=A,
        gather_width=TSP_MAX,
        gather=gather,
    )


def program_for_class(sz: dict):
    return make_tsp_program(sz["NC"])


CLASSES = {
    "S": dict(N=1 << 16, Hi=1, Hf=1, Ci=4 + 100, Cf=1, R=1 << 16, NC=10),
}
BUCKETS = [256, 1024, 4096]
