"""Blocked task-parallel matrix multiply (§6.5 programmability app).

  mm(ro, co, size): size <= 2 -> leaf: compute the 2x2 output block by a
                    fori_loop inner product (scatter-add free: disjoint
                    'set' writes into C)
                    else fork the four quadrant tasks (no join needed —
                    output blocks are disjoint)

const_f: A (n*n row-major) ++ B (n*n); heap_f: C (n*n)
const_i: [n]
"""

import jax
import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects

A = 4
B0 = 2  # leaf block edge
i32 = jnp.int32
f32 = jnp.float32

T_MM = 1


def make_matmul_program(NMAT: int) -> Program:
    def mm_fn(env, args, mask, child_slots):
        W = env.W
        n = env.const_i[0]
        ro, co, size = args[:, 0], args[:, 1], args[:, 2]
        leaf = size <= B0
        half = size // 2

        # --- leaf: 2x2 block inner products --------------------------
        def body(k, acc):
            accs = acc
            new = []
            for dr in range(B0):
                for dc in range(B0):
                    a = env.const_f[jnp.clip((ro + dr) * n + k, 0, NMAT * NMAT - 1)]
                    b = env.const_f[
                        jnp.clip(NMAT * NMAT + k * n + (co + dc), 0,
                                 2 * NMAT * NMAT - 1)]
                    new.append(accs[dr * B0 + dc] + a * b)
            return tuple(new)

        acc0 = tuple(jnp.zeros((W,), f32) for _ in range(B0 * B0))
        acc = jax.lax.fori_loop(0, n, body, acc0)
        scat = []
        for dr in range(B0):
            for dc in range(B0):
                idx = jnp.clip((ro + dr) * n + (co + dc), 0, NMAT * NMAT - 1)
                ok = mask & leaf & (ro + dr < n) & (co + dc < n)
                scat.append((idx, acc[dr * B0 + dc], ok, "set"))

        # --- split: four quadrants ------------------------------------
        fa = jnp.zeros((W, 4, A), i32)
        quads = [(0, 0), (0, 1), (1, 0), (1, 1)]
        for q, (qr, qc) in enumerate(quads):
            fa = fa.at[:, q, 0].set(ro + qr * half)
            fa = fa.at[:, q, 1].set(co + qc * half)
            fa = fa.at[:, q, 2].set(half)
        return Effects(
            fork_count=jnp.where(mask & ~leaf, 4, 0).astype(i32),
            fork_type=jnp.full((W, 4), T_MM, i32),
            fork_args=fa,
            heap_f_scatter=scat,
        )

    return Program(
        name="matmul",
        task_types=[TaskType("mm", mm_fn, max_forks=4)],
        num_args=A,
    )


def program_for_class(sz: dict):
    return make_matmul_program(sz["NMAT"])


def class_dict(NMAT: int, N: int) -> dict:
    return dict(N=N, Hi=1, Hf=NMAT * NMAT, Ci=1, Cf=2 * NMAT * NMAT, R=1,
                NMAT=NMAT)


CLASSES = {
    "S": class_dict(NMAT=16, N=1 << 10),
    "M": class_dict(NMAT=128, N=1 << 15),
}
BUCKETS = [256, 1024, 4096]
