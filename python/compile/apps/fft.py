"""Task-parallel FFT (Fig 6) — radix-2 decimation-in-frequency.

Matches the paper's setup: a fork/join FFT whose butterfly passes are
task trees (NO data-parallel map — §6.2 notes map is deliberately not
used, which would benefit TREES). Output is in bit-reversed order, as
is standard for in-place DIF; the Rust side applies the bit-reversal
permutation when checking numerics.

  fft(lo, n):  n <= 2 -> inline butterfly
               else fork bfr(lo, n, 0, n/2); join next(lo, n)
  bfr(lo, n, klo, khi): butterfly-range tree; leaves do <= 2 butterflies
               x[lo+k], x[lo+k+n/2] = a+b, (a-b)*w^k_n   (disjoint writes)
  next(lo, n): fork fft(lo, n/2), fft(lo+n/2, n/2)

heap_f: re[NMAX] ++ im[NMAX]
"""

import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects

A = 4
i32 = jnp.int32
f32 = jnp.float32

T_FFT = 1
T_BFR = 2
T_NEXT = 3


def make_fft_program(NMAX: int) -> Program:
    def butterfly_scatters(env, lo, n, k, active):
        """One butterfly per lane at global position k of block (lo,n)."""
        i0 = jnp.clip(lo + k, 0, NMAX - 1)
        i1 = jnp.clip(lo + k + n // 2, 0, NMAX - 1)
        re, im = env.heap_f, env.heap_f  # single array: re at [0,NMAX), im offset
        a_re = env.heap_f[i0]
        a_im = env.heap_f[NMAX + i0]
        b_re = env.heap_f[i1]
        b_im = env.heap_f[NMAX + i1]
        ang = -2.0 * jnp.pi * k.astype(f32) / jnp.maximum(n, 1).astype(f32)
        w_re = jnp.cos(ang)
        w_im = jnp.sin(ang)
        s_re = a_re + b_re
        s_im = a_im + b_im
        d_re = a_re - b_re
        d_im = a_im - b_im
        t_re = d_re * w_re - d_im * w_im
        t_im = d_re * w_im + d_im * w_re
        _ = (re, im)
        return [
            (i0, s_re, active, "set"),
            (NMAX + i0, s_im, active, "set"),
            (i1, t_re, active, "set"),
            (NMAX + i1, t_im, active, "set"),
        ]

    def fft_fn(env, args, mask, child_slots):
        W = env.W
        lo, n = args[:, 0], args[:, 1]
        tiny = n <= 2
        # inline butterfly for n == 2 (k = 0, twiddle 1)
        scat = butterfly_scatters(env, lo, n, jnp.zeros((W,), i32),
                                  mask & tiny & (n == 2))

        fa = jnp.zeros((W, 1, A), i32)
        fa = fa.at[:, 0, 0].set(lo)
        fa = fa.at[:, 0, 1].set(n)
        fa = fa.at[:, 0, 2].set(0)
        fa = fa.at[:, 0, 3].set(n // 2)
        ja = jnp.zeros((W, A), i32)
        ja = ja.at[:, 0].set(lo)
        ja = ja.at[:, 1].set(n)
        return Effects(
            fork_count=jnp.where(mask & ~tiny, 1, 0).astype(i32),
            fork_type=jnp.full((W, 1), T_BFR, i32),
            fork_args=fa,
            join_mask=~tiny,
            join_type=jnp.full((W,), T_NEXT, i32),
            join_args=ja,
            heap_f_scatter=scat,
        )

    def bfr_fn(env, args, mask, child_slots):
        W = env.W
        lo, n, klo, khi = args[:, 0], args[:, 1], args[:, 2], args[:, 3]
        small = (khi - klo) <= 2
        mid = (klo + khi) // 2
        # leaves: butterflies at klo and klo+1
        scat = butterfly_scatters(env, lo, n, klo, mask & small)
        scat += butterfly_scatters(env, lo, n, klo + 1,
                                   mask & small & (klo + 1 < khi))

        fa = jnp.zeros((W, 2, A), i32)
        fa = fa.at[:, 0, 0].set(lo)
        fa = fa.at[:, 0, 1].set(n)
        fa = fa.at[:, 0, 2].set(klo)
        fa = fa.at[:, 0, 3].set(mid)
        fa = fa.at[:, 1, 0].set(lo)
        fa = fa.at[:, 1, 1].set(n)
        fa = fa.at[:, 1, 2].set(mid)
        fa = fa.at[:, 1, 3].set(khi)
        return Effects(
            fork_count=jnp.where(mask & ~small, 2, 0).astype(i32),
            fork_type=jnp.full((W, 2), T_BFR, i32),
            fork_args=fa,
            heap_f_scatter=scat,
        )

    def next_fn(env, args, mask, child_slots):
        W = env.W
        lo, n = args[:, 0], args[:, 1]
        h = n // 2
        recurse = h >= 2
        fa = jnp.zeros((W, 2, A), i32)
        fa = fa.at[:, 0, 0].set(lo)
        fa = fa.at[:, 0, 1].set(h)
        fa = fa.at[:, 1, 0].set(lo + h)
        fa = fa.at[:, 1, 1].set(h)
        return Effects(
            fork_count=jnp.where(mask & recurse, 2, 0).astype(i32),
            fork_type=jnp.full((W, 2), T_FFT, i32),
            fork_args=fa,
        )

    return Program(
        name="fft",
        task_types=[
            TaskType("fft", fft_fn, max_forks=1),
            TaskType("bfr", bfr_fn, max_forks=2),
            TaskType("next", next_fn, max_forks=2),
        ],
        num_args=A,
    )


def program_for_class(sz: dict):
    return make_fft_program(sz["NMAX"])


def class_dict(NMAX: int, N: int) -> dict:
    return dict(N=N, Hi=1, Hf=2 * NMAX, Ci=1, Cf=1, R=1, NMAX=NMAX)


CLASSES = {
    "S": class_dict(NMAX=1 << 10, N=1 << 13),
    "M": class_dict(NMAX=1 << 16, N=1 << 19),
}
BUCKETS = [256, 1024, 4096]
