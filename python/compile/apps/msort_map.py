"""Sophisticated TREES mergesort (Fig 9): merges via the data-parallel
map operation (merge-path kernel), closing most of the gap to the
native bitonic sort."""

from ._msort import class_dict, make_msort_program


def program_for_class(sz: dict):
    return make_msort_program("msort_map", True, sz["NMAX"])


CLASSES = {
    "S": class_dict(NMAX=1 << 10, N=1 << 12),
    "M": class_dict(NMAX=1 << 16, N=1 << 19),
}
BUCKETS = [256, 1024, 4096]
MAP_BUCKETS = [4096]
