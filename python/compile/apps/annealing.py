"""Parallel simulated annealing (§6.5 app): many independent chains,
each a sequence of tasks (the continuation-passing style the TVM
requires — each step forks its successor).

  root(chains, steps): fork chain(x0_c, 0, steps, c) per chain (c < K=8)
  chain(x, step, steps, c): propose x' = neighbor(x, hash); accept by
      Metropolis with hash-derived threshold (deterministic: both the
      artifact and the interpreter compute the same decision);
      publish energy bound to heap_i[0] (min-merge);
      step+1 < steps -> fork continuation else emit best energy

Energy: a rugged integer hash landscape  e(x) = popcount-weighted mix —
no external data needed. Deterministic across layers.

heap_i: [0] = best energy seen (global min-merge)
const_i: [steps, n_chains, temp0, reserved]
"""

import jax.numpy as jnp

from ..treeslang import TaskType, Program, Effects

A = 4
K_CHAINS = 8
i32 = jnp.int32
u32 = jnp.uint32

T_ROOT = 1
T_CHAIN = 2


def _mix(x):
    """xorshift-mult hash, matching rust apps::annealing::mix."""
    x = x.astype(u32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def energy(x):
    """Rugged landscape in [0, 2^16)."""
    return (_mix(x) & jnp.uint32(0xFFFF)).astype(i32)


def _root_fn(env, args, mask, child_slots):
    W = env.W
    steps = env.const_i[0]
    nchains = env.const_i[1]
    fa = jnp.zeros((W, K_CHAINS, A), i32)
    for c in range(K_CHAINS):
        x0 = (_mix(jnp.full((W,), c * 7919 + 13, i32)) & jnp.uint32(0xFFFFF))
        fa = fa.at[:, c, 0].set(x0.astype(i32))
        fa = fa.at[:, c, 1].set(0)
        fa = fa.at[:, c, 2].set(steps)
        fa = fa.at[:, c, 3].set(c)
    return Effects(
        fork_count=jnp.where(mask, jnp.minimum(nchains, K_CHAINS), 0),
        fork_type=jnp.full((W, K_CHAINS), T_CHAIN, i32),
        fork_args=fa,
    )


def _chain_fn(env, args, mask, child_slots):
    W = env.W
    x, step, steps, c = args[:, 0], args[:, 1], args[:, 2], args[:, 3]
    h = _mix(x * 31 + step * 101 + c * 1009)
    # neighbor: flip one of the low 20 bits
    bit = (h % 20).astype(i32)
    x2 = x ^ (1 << bit)
    e1 = energy(x)
    e2 = energy(x2)
    # Metropolis: accept if better, else with prob exp(-(de)/T); the
    # threshold comes from the hash (deterministic). T decays with step.
    t = jnp.maximum(1, env.const_i[2] - step)  # linear cooling
    de = e2 - e1
    r = (_mix(h) & jnp.uint32(0x3FF)).astype(i32)  # 0..1023
    # accept iff de <= 0 or r < 1024 * exp(-de/t) ~ approx via shift:
    accept = (de <= 0) | (r < (1024 * t) // jnp.maximum(de * 4 + t, 1))
    xn = jnp.where(accept, x2, x)
    en = jnp.minimum(e1, jnp.where(accept, e2, e1))

    last = step + 1 >= steps
    fa = jnp.zeros((W, K_CHAINS, A), i32)
    fa = fa.at[:, 0, 0].set(xn)
    fa = fa.at[:, 0, 1].set(step + 1)
    fa = fa.at[:, 0, 2].set(steps)
    fa = fa.at[:, 0, 3].set(c)
    return Effects(
        fork_count=jnp.where(mask & ~last, 1, 0).astype(i32),
        fork_type=jnp.full((W, K_CHAINS), T_CHAIN, i32),
        fork_args=fa,
        emit_mask=last,
        emit_val=en,
        heap_i_scatter=[(jnp.zeros((W,), i32), en, mask, "min")],
    )


def program():
    return Program(
        name="annealing",
        task_types=[
            TaskType("root", _root_fn, max_forks=K_CHAINS),
            TaskType("chain", _chain_fn, max_forks=1),
        ],
        num_args=A,
    )


CLASSES = {
    "S": dict(N=1 << 14, Hi=1, Hf=1, Ci=4, Cf=1, R=1),
}
BUCKETS = [256]
