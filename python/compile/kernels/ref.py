"""Pure-jnp/numpy oracles for every Pallas kernel — the core L1
correctness signal (each kernel's pytest asserts allclose against
these)."""

import numpy as np

INF = 1 << 30


def exclusive_scan_ref(x):
    """scan[i] = sum(x[:i]); also returns total."""
    x = np.asarray(x)
    c = np.cumsum(x)
    return c - x, int(c[-1]) if len(x) else 0


def relax_ref(dist, esrc, ew, frontier):
    """nd[e] = dist[esrc[e]] + ew[e] if esrc[e] active & reached else INF."""
    dist = np.asarray(dist)
    esrc = np.asarray(esrc)
    out = np.full(len(esrc), INF, np.int64)
    for e, s in enumerate(esrc):
        if frontier[s] != 0 and dist[s] < INF:
            out[e] = int(dist[s]) + int(ew[e])
    return out.astype(np.int32)


def bitonic_sort_ref(x):
    return np.sort(np.asarray(x))


def merge_level_ref(buf, size, total, src_off, nmax):
    """Merge all `size`-wide (2R) blocks of buf[src_off:src_off+nmax];
    positions >= total are +inf."""
    buf = np.asarray(buf)
    out = np.full(nmax, np.inf, np.float32)
    if size <= 0:
        return out
    nblocks = total // size
    for b in range(nblocks):
        lo = b * size
        run = np.sort(np.concatenate([
            buf[src_off + lo:src_off + lo + size // 2],
            buf[src_off + lo + size // 2:src_off + lo + size],
        ]))
        out[lo:lo + size] = run
    return out
