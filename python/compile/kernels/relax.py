"""Edge-frontier relaxation Pallas kernel — the hot loop of the native
(hand-coded, Lonestar-style) BFS/SSSP baselines of Fig 7/8.

Per edge e with src[e] in the frontier: propose nd[e] = dist[src[e]] +
w[e]. The caller scatter-mins the proposals into dist and derives the
next frontier. The kernel covers the bandwidth-bound gather+add; edges
stream through VMEM in tiles while the dist array stays resident.

TPU mapping: dist (<= 64 KiB for the M class) is pinned in VMEM; edge
tiles (src/weight) stream HBM->VMEM via BlockSpec; the gather uses the
VPU's dynamic-slice path. interpret=True mandatory on this install.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 1 << 30
TILE = 8192


def _relax_kernel(dist_ref, esrc_ref, ew_ref, frontier_ref, nd_ref):
    dist = dist_ref[...]
    frontier = frontier_ref[...]
    src = esrc_ref[...]
    d = dist[src]
    active = (frontier[src] != 0) & (d < INF)
    nd_ref[...] = jnp.where(active, d + ew_ref[...], INF)


def relax_proposals(dist, esrc, ew, frontier, *, interpret: bool = True):
    """nd[e] = dist[esrc[e]] + ew[e] where esrc[e] is in the frontier,
    else INF. dist/frontier: i32[V]; esrc/ew: i32[E], E % TILE == 0 or
    E <= TILE."""
    (e,) = esrc.shape
    if e <= TILE:
        return pl.pallas_call(
            _relax_kernel,
            out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
            interpret=interpret,
        )(dist, esrc, ew, frontier)
    if e % TILE != 0:
        raise ValueError(f"edge count {e} not a multiple of {TILE}")
    (v,) = dist.shape
    grid = (e // TILE,)
    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((v,), lambda i: (0,)),  # dist resident
            pl.BlockSpec((TILE,), lambda i: (i,)),  # edge tile
            pl.BlockSpec((TILE,), lambda i: (i,)),
            pl.BlockSpec((v,), lambda i: (0,)),  # frontier resident
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.int32),
        interpret=interpret,
    )(dist, esrc, ew, frontier)
