"""Bitonic sorting network as a Pallas kernel per merge stage.

Each (k, j) stage compares element i with its partner i^j and swaps to
enforce the bitonic order — a perfectly regular, coalesced pattern (the
reason the paper picks bitonic sort as the native GPU comparator).

TPU mapping: each stage is one VMEM-resident map over the array; the
partner access is a strided shuffle. interpret=True mandatory here.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stage_kernel(k_ref, j_ref, x_ref, o_ref):
    x = x_ref[...]
    (n,) = x.shape
    i = jnp.arange(n, dtype=jnp.int32)
    k = k_ref[0]
    j = j_ref[0]
    partner = i ^ j
    px = x[partner]
    up = (i & k) == 0  # ascending block?
    keep_lo = jnp.where(up, jnp.minimum(x, px), jnp.maximum(x, px))
    keep_hi = jnp.where(up, jnp.maximum(x, px), jnp.minimum(x, px))
    o_ref[...] = jnp.where(partner > i, keep_lo, keep_hi)


def bitonic_stage(x, k: int, j: int, *, interpret: bool = True):
    (n,) = x.shape
    return pl.pallas_call(
        _stage_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(jnp.array([k], jnp.int32), jnp.array([j], jnp.int32), x)


def bitonic_sort(x, *, interpret: bool = True):
    """Full ascending bitonic sort of a power-of-two-length array."""
    (n,) = x.shape
    assert n & (n - 1) == 0, "bitonic sort needs power-of-two length"
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            x = bitonic_stage(x, k, j, interpret=interpret)
            j //= 2
        k *= 2
    return x
