"""Exclusive prefix-sum (scan) Pallas kernel — the fork allocator.

This is the load-bearing L1 kernel of the runtime itself: every epoch,
fork slots are assigned `next_free + exclusive_scan(fork_count)`. It is
the work-together (Tenet 2) replacement for the paper's per-wavefront
atomic increment of `nextFreeCore`: all lanes cooperatively compute their
slots with coalesced reads/writes and zero atomics.

Structure (two passes, classic scan-then-propagate):
  pass 1: grid over chunks; each chunk writes its local exclusive scan
          and its chunk total (one VMEM-resident block per grid step).
  bridge: exclusive scan of the (tiny) chunk totals — plain jnp.
  pass 2: grid over chunks; adds the chunk offset to each element.

TPU mapping (documented for DESIGN.md §Hardware-Adaptation): each chunk
is a VMEM block; BlockSpec index_map streams HBM->VMEM chunk by chunk;
the within-chunk cumsum vectorizes on the VPU (8x128 lanes). interpret
mode is mandatory on this CPU-only install — see aot notes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk size: one VMEM block. 1024 i32 = 4 KiB, comfortably inside the
# ~16 MiB VMEM budget even with double buffering.
CHUNK = 1024


def _scan_chunk_kernel(x_ref, ex_ref, tot_ref):
    x = x_ref[...]
    c = jnp.cumsum(x)
    ex_ref[...] = c - x
    tot_ref[...] = c[-1:]  # chunk total (shape (1,))


def _add_offset_kernel(ex_ref, off_ref, o_ref):
    o_ref[...] = ex_ref[...] + off_ref[0]


def exclusive_scan(x: jnp.ndarray, *, interpret: bool = True):
    """Exclusive prefix sum of a 1-D i32 array.

    Returns (scan, total) where scan[i] = sum(x[:i]) and total = sum(x).
    Length must be a multiple of CHUNK or smaller than CHUNK.
    """
    (n,) = x.shape
    if n <= CHUNK:
        # single chunk: one kernel invocation, no bridge needed
        ex, tot = pl.pallas_call(
            _scan_chunk_kernel,
            out_shape=(
                jax.ShapeDtypeStruct((n,), x.dtype),
                jax.ShapeDtypeStruct((1,), x.dtype),
            ),
            interpret=interpret,
        )(x)
        return ex, tot[0]
    if n % CHUNK != 0:
        raise ValueError(f"scan length {n} not a multiple of {CHUNK}")
    nchunks = n // CHUNK

    ex, tots = pl.pallas_call(
        _scan_chunk_kernel,
        grid=(nchunks,),
        in_specs=[pl.BlockSpec((CHUNK,), lambda i: (i,))],
        out_specs=(
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((nchunks,), x.dtype),
        ),
        interpret=interpret,
    )(x)

    offs = jnp.cumsum(tots) - tots  # bridge scan: nchunks elements, tiny
    total = offs[-1] + tots[-1]

    out = pl.pallas_call(
        _add_offset_kernel,
        grid=(nchunks,),
        in_specs=[
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((CHUNK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(ex, offs)
    return out, total
