"""Merge-path kernel: one data-parallel pass merging all same-size run
pairs of a mergesort level — the map operation of the sophisticated
TREES mergesort (Fig 9).

For output element i: block = i // (2R), j = i - block*2R; binary-search
the merge-path partition a (elements taken from the left run among the
first j outputs), then out = min(L[a], R[j-a]) with +inf sentinels.
O(log R) gathers per element, perfectly regular — the GPU-friendly merge
the paper's map operation is meant to enable.

TPU mapping: the source buffer stays VMEM-resident (<= 512 KiB for the
M class); output tiles stream; the binary search is a fixed-trip
fori_loop on the VPU. interpret=True mandatory on this install.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

i32 = jnp.int32
f32 = jnp.float32
TILE = 8192
SEARCH_ITERS = 21  # supports runs up to 2^20


def _merge_kernel(params_ref, buf_ref, o_ref, *, nmax: int, tile: int):
    size = params_ref[0]  # 2R (block size at this level)
    total = params_ref[1]  # number of valid output elements
    src_off = params_ref[2]
    tstart = params_ref[3] if params_ref.shape[0] > 3 else 0

    pid = pl.program_id(0) if o_ref.shape[0] != nmax else 0
    i = tstart + pid * tile + jnp.arange(tile, dtype=i32)
    buf = buf_ref[...]

    r = size // 2
    block = i // jnp.maximum(size, 1)
    lo = block * size
    j = i - lo
    mid = lo + r

    def left(a):
        # L[a] with +inf when a >= r (or out of the valid region)
        idx = jnp.clip(src_off + lo + a, 0, buf.shape[0] - 1)
        return jnp.where(a < r, buf[idx], jnp.inf)

    def right(b):
        idx = jnp.clip(src_off + mid + b, 0, buf.shape[0] - 1)
        return jnp.where(b < r, buf[idx], jnp.inf)

    # find the largest a in [max(0, j-r), min(j, r)] with L[a-1] <= R[j-a]
    lo_a = jnp.maximum(0, j - r)
    hi_a = jnp.minimum(j, r)

    def body(_, carry):
        lo_a, hi_a = carry
        a = (lo_a + hi_a + 1) // 2
        ok = (a <= lo_a) | (left(a - 1) <= right(j - a))
        return jnp.where(ok, a, lo_a), jnp.where(ok, hi_a, a - 1)

    lo_a, hi_a = jax.lax.fori_loop(0, SEARCH_ITERS, body, (lo_a, hi_a))
    a = lo_a
    out = jnp.minimum(left(a), right(j - a))
    o_ref[...] = jnp.where(i < total, out, jnp.inf)


def merge_level(buf, size, total, src_off, *, nmax: int, interpret: bool = True):
    """Merge all 2R-sized blocks of `buf[src_off:src_off+nmax]`.

    Returns the merged values for output positions [0, nmax) (positions
    >= total are +inf). `size`, `total`, `src_off` are traced scalars.
    """
    params = jnp.stack([size, total, src_off, jnp.zeros((), i32)])
    if nmax <= TILE:
        import functools

        return pl.pallas_call(
            functools.partial(_merge_kernel, nmax=nmax, tile=nmax),
            out_shape=jax.ShapeDtypeStruct((nmax,), f32),
            interpret=interpret,
        )(params, buf)
    import functools

    assert nmax % TILE == 0
    grid = (nmax // TILE,)
    return pl.pallas_call(
        functools.partial(_merge_kernel, nmax=nmax, tile=TILE),
        grid=grid,
        in_specs=[
            pl.BlockSpec((4,), lambda i: (0,)),
            pl.BlockSpec(buf.shape, lambda i: (0,)),  # resident source
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nmax,), f32),
        interpret=interpret,
    )(params, buf)
