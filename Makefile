# TREES — build / test entry points.
#
#   make check      tier-1: release build + full test suite + clippy +
#                   rustdoc (offline; artifact e2e tests self-skip
#                   without artifacts)
#   make clippy     cargo clippy, warnings denied
#   make doc        cargo doc --no-deps, rustdoc warnings denied
#   make fmt        rustfmt the workspace
#   make fmt-check  rustfmt in --check mode (CI)
#   make artifacts  AOT-lower the epoch-step programs to HLO text
#                   (needs the python/compile JAX toolchain)
#   make bench      run all paper benches (skip-aware)
#   make bench-hybrid
#                   the E-HYBRID-1 crossover bench alone: modeled µs
#                   under --engine cpu/gpu/auto per mix, snapshotted
#                   to BENCH_hybrid.json (asserts auto never loses to
#                   pure GPU and wins >=1.2x on the narrow-front mix)
#   make bench-hetero
#                   the E-HETERO-1 mixed-SKU bench alone: speed-blind
#                   greedy vs LPT+slice-steals on a 1.0/0.25 pair,
#                   snapshotted to BENCH_hetero.json (asserts aware
#                   never loses and wins >=1.2x on the time-skewed mix)
#   make inspect-smoke
#                   record a `trees trace` run, replay the recording
#                   through `trees inspect --invariants strict`, and
#                   diff the two summary blocks (byte-identical gate)

CARGO ?= cargo

.PHONY: check build test clippy doc fmt fmt-check artifacts bench \
        bench-hybrid bench-hetero pytest inspect-smoke

check: build test clippy doc

build:
	cd rust && $(CARGO) build --release

test:
	cd rust && TREES_FAULT_SEEDS=0..4 $(CARGO) test -q

clippy:
	cd rust && $(CARGO) clippy --all-targets -- -D warnings

doc:
	cd rust && RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --lib

fmt:
	cd rust && $(CARGO) fmt --all

fmt-check:
	cd rust && $(CARGO) fmt --all --check

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

pytest:
	cd python && python -m pytest -q tests

bench:
	cd rust && $(CARGO) bench

bench-hybrid:
	cd rust && $(CARGO) bench --bench bench_hybrid

bench-hetero:
	cd rust && $(CARGO) bench --bench bench_hetero

# The flight-recorder e2e gate: a live `trees trace` run and a
# `trees inspect` replay of its own recording must print the same
# summary block byte for byte, with strict invariants clean.
inspect-smoke: build
	@set -e; \
	tmp=$$(mktemp -d); trap 'rm -rf "$$tmp"' EXIT; \
	bin=rust/target/release/trees; \
	$$bin trace --jobs fib:12,mergesort:64@3,nqueens:5@5 --devices 2 \
	    --fault-plan die:1@4 --invariants strict \
	    > "$$tmp/rec.ndjson" 2> "$$tmp/live.log"; \
	sed -n '/== trace summary ==/,/== end summary ==/p' \
	    "$$tmp/live.log" > "$$tmp/live.sum"; \
	$$bin inspect --file "$$tmp/rec.ndjson" --invariants strict \
	    > "$$tmp/replay.out"; \
	sed -n '/== trace summary ==/,/== end summary ==/p' \
	    "$$tmp/replay.out" > "$$tmp/replay.sum"; \
	diff -u "$$tmp/live.sum" "$$tmp/replay.sum"; \
	echo "inspect-smoke: live and replayed summaries are byte-identical"
