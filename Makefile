# TREES — build / test entry points.
#
#   make check      tier-1: release build + full test suite + clippy +
#                   rustdoc (offline; artifact e2e tests self-skip
#                   without artifacts)
#   make clippy     cargo clippy, warnings denied
#   make doc        cargo doc --no-deps, rustdoc warnings denied
#   make fmt        rustfmt the workspace
#   make fmt-check  rustfmt in --check mode (CI)
#   make artifacts  AOT-lower the epoch-step programs to HLO text
#                   (needs the python/compile JAX toolchain)
#   make bench      run all paper benches (skip-aware)

CARGO ?= cargo

.PHONY: check build test clippy doc fmt fmt-check artifacts bench pytest

check: build test clippy doc

build:
	cd rust && $(CARGO) build --release

test:
	cd rust && TREES_FAULT_SEEDS=0..4 $(CARGO) test -q

clippy:
	cd rust && $(CARGO) clippy --all-targets -- -D warnings

doc:
	cd rust && RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --lib

fmt:
	cd rust && $(CARGO) fmt --all

fmt-check:
	cd rust && $(CARGO) fmt --all --check

artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

pytest:
	cd python && python -m pytest -q tests

bench:
	cd rust && $(CARGO) bench
