//! Multi-device sharding behind the `Session` facade: partition a
//! multi-tenant job mix across a simulated device group, admit late
//! arrivals online, and watch the rebalancer move tenants at epoch
//! boundaries.
//!
//!     cargo run --release --example sharded_service
//!
//! Eight tenants are served over two devices with least-loaded
//! placement; two of them arrive mid-run (`@epoch` in the feed) and
//! land on whichever device has drained — online admission and
//! placement working together. When live-lane skew crosses the
//! threshold the group migrates tenants over — whole machine state
//! moves at the epoch boundary, so every result still verifies against
//! its solo oracle. No artifacts needed: pure-Rust engines.

use trees::session::Session;
use trees::shard::PlacementKind;
use trees::simt::{DeviceGroup, GpuModel};

fn main() -> anyhow::Result<()> {
    let mut session = Session::builder()
        .devices(2)
        .placement(PlacementKind::LeastLoaded)
        .trace(true)
        .build()?;

    // six tenants up front…
    for tok in [
        "fib:16",
        "fib:15",
        "fib:14",
        "mergesort:64",
        "mergesort:32",
        "nqueens:5",
    ] {
        session.submit_spec(tok)?;
    }
    // …run a while, then two more arrive online (built at submit time)
    for _ in 0..8 {
        session.step()?;
    }
    for tok in ["fib:14", "mergesort:16"] {
        let id = session.submit_spec(tok)?;
        println!("@{} admitted {id} {tok} (online)", session.steps());
    }
    session.drain()?;

    println!("\nper-tenant results (verified against app oracles):");
    let mut rows: Vec<_> = session.results().iter().collect();
    rows.sort_by_key(|r| r.job.id.0);
    for r in rows {
        assert_eq!(r.verified(), Some(true), "{}", r.job.label);
        println!(
            "  {}  {:<16} {:<28} rode {} epochs, stalled {}",
            r.device,
            r.job.label,
            r.summary(),
            r.job.stats.steps_ridden,
            r.job.stats.stalls
        );
    }

    let s = session.shard_stats().expect("two devices");
    println!("\nmigrations (epoch-boundary, whole-tenant):");
    for e in &s.migration_log {
        println!("  step {:>3}: {} moved {} -> {}", e.step, e.job, e.from, e.to);
    }
    let model = DeviceGroup::new(GpuModel::default(), session.devices());
    println!(
        "\n{} group epochs over {} devices | {} launches | peak live-lane \
         imbalance {:.2}x | modeled group APU {:.0} us (barrier {:.0} us/step)",
        s.group_steps,
        session.devices(),
        session.stats().launches,
        s.peak_imbalance,
        trees::shard::modeled_group_us(&model, &s.trace),
        model.barrier_us(),
    );
    Ok(())
}
