//! Multi-device sharding: partition a multi-tenant job mix across a
//! simulated device group, then watch the rebalancer move tenants at
//! epoch boundaries.
//!
//!     cargo run --release --example sharded_service
//!
//! Eight tenants are placed over two devices with app affinity (fibs
//! together, sorts together — the locality policy). The sorts drain
//! first, the sort device idles, live-lane skew crosses the threshold,
//! and the group migrates fib tenants over — whole machine state moves
//! at the epoch boundary, so every result still verifies against its
//! solo oracle. No artifacts needed: pure-Rust engines.

use trees::sched::{JobSpec, SchedConfig};
use trees::shard::{
    modeled_group_us, PlacementKind, RebalanceCfg, ShardConfig, ShardGroup,
};
use trees::simt::{DeviceGroup, GpuModel};

fn main() -> anyhow::Result<()> {
    let specs = JobSpec::parse_list(
        "fib:16,fib:15,fib:14,fib:14,mergesort:64,mergesort:32,\
         mergesort:16,nqueens:5",
    )?;
    let builds: Vec<_> = specs
        .iter()
        .map(|s| s.instantiate())
        .collect::<anyhow::Result<_>>()?;

    let mut group = ShardGroup::new(ShardConfig {
        devices: 2,
        placement: PlacementKind::Affinity,
        rebalance: RebalanceCfg::default(),
        sched: SchedConfig { trace: true, ..Default::default() },
    });
    group.pin("fib", 0);
    group.pin("mergesort", 1);
    group.pin("nqueens", 1);
    for b in &builds {
        group.admit_build(b);
    }
    group.run_to_completion()?;

    println!("per-tenant results (verified against app oracles):");
    let mut rows: Vec<_> = group.finished().collect();
    rows.sort_by_key(|(_, fj)| fj.id.0);
    for (dev, fj) in rows {
        let m = fj.engine.machine().expect("interp engine");
        let kind = fj.kind.as_ref().unwrap();
        kind.verify(m).map_err(anyhow::Error::msg)?;
        println!(
            "  {dev}  {:<16} {:<28} rode {} epochs, stalled {}",
            fj.label,
            kind.describe(m),
            fj.stats.steps_ridden,
            fj.stats.stalls
        );
    }

    let s = group.stats();
    println!("\nmigrations (epoch-boundary, whole-tenant):");
    for e in &s.migration_log {
        println!("  step {:>3}: {} moved {} -> {}", e.step, e.job, e.from, e.to);
    }
    let model = DeviceGroup::new(GpuModel::default(), group.devices());
    println!(
        "\n{} group epochs over {} devices | {} launches | peak live-lane \
         imbalance {:.2}x | modeled group APU {:.0} us (barrier {:.0} us/step)",
        s.group_steps,
        group.devices(),
        group.total_launches(),
        s.peak_imbalance,
        modeled_group_us(&model, &s.trace),
        model.barrier_us(),
    );
    Ok(())
}
