//! Fig 9 in miniature: the three sorting configurations side by side —
//! naive TREES mergesort (serial merge tasks), TREES + data-parallel
//! map merges, and the hand-coded native bitonic network.
//!
//!     make artifacts && cargo run --release --example sorting_showdown

use trees::apps::msort;
use trees::baselines::Bitonic;
use trees::benchkit::Table;
use trees::coordinator::{Coordinator, CoordinatorConfig};
use trees::runtime::{load_manifest, Device};
use trees::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let (manifest, dir) = load_manifest()?;
    let dev = Device::cpu()?;
    let n = 1024usize;
    let mut rng = Rng::new(99);
    let xs: Vec<f32> = (0..n).map(|_| rng.f32() * 1e4).collect();
    let mut want = xs.clone();
    want.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let mut table = Table::new(
        &format!("sorting {n} floats"),
        &["config", "time ms", "epochs", "map launches", "sorted"],
    );

    for app_name in ["mergesort", "msort_map"] {
        let app = manifest.app(app_name)?;
        let (w, nmax, n2) = msort::workload(app, &xs)?;
        let co = Coordinator::for_workload(&dev, &dir, app, &w,
            CoordinatorConfig::default())?;
        let t0 = std::time::Instant::now();
        let (st, stats) = co.run(&w)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let off = msort::final_offset(nmax, n2);
        let ok = st.heap_f[off..off + n] == want[..];
        assert!(ok, "{app_name} mis-sorted");
        table.row(vec![
            (if app_name == "mergesort" { "TREES naive" } else { "TREES + map" }).into(),
            format!("{ms:.1}"),
            format!("{}", stats.epochs),
            format!("{}", stats.map_launches),
            "yes".into(),
        ]);
    }

    let b = Bitonic::new(&dev, &dir, manifest.app("native_bitonic")?, n)?;
    let t0 = std::time::Instant::now();
    let got = b.sort(&xs)?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(got, want);
    table.row(vec!["native bitonic".into(), format!("{ms:.1}"), "-".into(),
                   "-".into(), "yes".into()]);
    table.print();
    Ok(())
}
