//! Quickstart: run a task-parallel program on the TREES runtime.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Loads the AOT-compiled fib epoch-step, drives it through the
//! coordinator, and cross-checks against the sequential TVM
//! interpreter — the whole three-layer stack in ~40 lines.

use trees::apps::fib;
use trees::coordinator::{Coordinator, CoordinatorConfig};
use trees::runtime::{load_manifest, Device};
use trees::tvm::Interp;

fn main() -> anyhow::Result<()> {
    let (manifest, dir) = load_manifest()?;
    let dev = Device::cpu()?;

    let n = 22u32;
    let w = fib::workload(n);
    let app = manifest.app("fib")?;
    let co = Coordinator::for_workload(&dev, &dir, app, &w, CoordinatorConfig::default())?;

    let (state, stats) = co.run(&w)?;
    println!("fib({n}) = {}", state.root_result());
    println!(
        "  {} epochs (T-inf), {} tasks (T1), {} bulk launches, peak TV {}",
        stats.epochs, stats.work, stats.launches, stats.peak_tv
    );

    // the sequential Task Vector Machine gives the same answer and the
    // same machine-model quantities
    let mut oracle = Interp::new(&trees::apps::Fib, fib::capacity_for(n), vec![n as i32]);
    let ostats = oracle.run();
    assert_eq!(oracle.root_result(), state.root_result());
    assert_eq!(ostats.epochs, stats.epochs);
    println!("  sequential TVM oracle agrees (epochs & result)");
    Ok(())
}
