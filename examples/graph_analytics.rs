//! End-to-end driver (the repo's full-system workout): generate the
//! three graph families, run TREES BFS and SSSP through the AOT
//! artifacts, run the hand-coded native worklist baselines, verify
//! everything against reference algorithms, and report the Fig 7/8
//! comparison — all in one binary.
//!
//!     make artifacts && cargo run --release --example graph_analytics

use trees::apps::graph_sp;
use trees::baselines::Worklist;
use trees::benchkit::Table;
use trees::coordinator::{Coordinator, CoordinatorConfig};
use trees::graph::{bfs_levels, dijkstra, gen};
use trees::runtime::{load_manifest, Device};

fn main() -> anyhow::Result<()> {
    let (manifest, dir) = load_manifest()?;
    let dev = Device::cpu()?;

    let graphs = vec![
        ("rmat-10".to_string(), gen::rmat(10, 8, 10, 1)),
        ("grid-40".to_string(), gen::grid2d(40, 10, 2)),
        ("uniform-2k".to_string(), gen::uniform(2048, 4, 10, 3)),
    ];

    for algo in ["bfs", "sssp"] {
        let app = manifest.app(algo)?;
        let napp = manifest.app(&format!("native_{algo}"))?;
        let mut table = Table::new(
            &format!("{algo}: TREES vs native worklist"),
            &["graph", "V", "E", "trees ms", "native ms", "epochs", "verified"],
        );
        for (name, g) in &graphs {
            let src = 0usize;
            let (w, _) = graph_sp::workload(app, g, src)?;
            let co = Coordinator::for_workload(&dev, &dir, app, &w,
                CoordinatorConfig::default())?;
            let t0 = std::time::Instant::now();
            let (st, stats) = co.run(&w)?;
            let trees_ms = t0.elapsed().as_secs_f64() * 1e3;

            let wl = Worklist::new(&dev, &dir, napp, g)?;
            let t1 = std::time::Instant::now();
            let (ndist, _) = wl.run(g, src)?;
            let native_ms = t1.elapsed().as_secs_f64() * 1e3;

            let want = if algo == "bfs" { bfs_levels(g, src) } else { dijkstra(g, src) };
            let ok = st.heap_i[..g.num_vertices()] == want[..] && ndist == want;
            assert!(ok, "{algo}/{name} mismatch");

            table.row(vec![
                name.clone(),
                format!("{}", g.num_vertices()),
                format!("{}", g.num_edges()),
                format!("{trees_ms:.1}"),
                format!("{native_ms:.1}"),
                format!("{}", stats.epochs),
                "yes".into(),
            ]);
        }
        table.print();
    }
    println!("\nall distances verified against BFS/Dijkstra references.");
    Ok(())
}
