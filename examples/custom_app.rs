//! Programmability (§6.5): writing a NEW task-parallel application
//! against the TVM interface — here, parallel array-max via fork/join
//! reduction — and running it on the sequential TVM interpreter.
//!
//! (AOT-compiling a new app additionally needs its ~60-line vectorized
//! twin in python/compile/apps/ — see fib.py for the template.)
//!
//!     cargo run --release --example custom_app

use trees::tvm::{Interp, TaskCtx, TvmProgram};

/// max(lo, hi): small range -> emit local max
///              else fork halves; join max2(slot_a, slot_b)
struct ArrayMax;

const T_MAX: usize = 1;
const T_MAX2: usize = 2;

impl TvmProgram for ArrayMax {
    fn num_task_types(&self) -> usize {
        2
    }

    fn run_task(&self, tid: usize, args: &[i32], ctx: &mut TaskCtx) {
        match tid {
            T_MAX => {
                let (lo, hi) = (args[0], args[1]);
                if hi - lo <= 4 {
                    let m = (lo..hi).map(|i| ctx.const_i[i as usize]).max().unwrap();
                    ctx.emit(m);
                } else {
                    let mid = (lo + hi) / 2;
                    let a = ctx.fork(T_MAX, vec![lo, mid]) as i32;
                    let b = ctx.fork(T_MAX, vec![mid, hi]) as i32;
                    ctx.join(T_MAX2, vec![a, b]);
                }
            }
            T_MAX2 => {
                ctx.emit(ctx.res[args[0] as usize].max(ctx.res[args[1] as usize]));
            }
            _ => unreachable!(),
        }
    }
}

fn main() {
    let data: Vec<i32> = (0..10_000).map(|i| (i * 2654435761u64 as i64 % 99991) as i32).collect();
    let want = *data.iter().max().unwrap();

    let mut m = Interp::new(&ArrayMax, 1 << 16, vec![0, data.len() as i32])
        .with_heaps(vec![], vec![], data, vec![]);
    let stats = m.run();
    println!("parallel max = {} (reference {})", m.root_result(), want);
    assert_eq!(m.root_result(), want);
    println!(
        "T1 = {} tasks, T-inf = {} epochs, parallelism = {:.0}",
        stats.work,
        stats.epochs,
        stats.work as f64 / stats.epochs as f64
    );
}
