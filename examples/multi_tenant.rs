//! Multi-tenant epoch fusion: serve several concurrent jobs from one
//! shared epoch loop.
//!
//!     cargo run --release --example multi_tenant
//!
//! Three heterogeneous tenants (fib, BFS, mergesort) are admitted to
//! the fused scheduler. Each shared epoch packs their live task fronts
//! into one task vector at per-job base offsets, so a single launch and
//! a single epoch synchronization pay V∞ for everyone — then each
//! result is cross-checked against a dedicated solo run. No artifacts
//! needed: this drives the pure-Rust fused engine.

use trees::sched::{FusedScheduler, JobSpec, SchedConfig};
use trees::simt::GpuModel;

fn main() -> anyhow::Result<()> {
    let specs = JobSpec::parse_list("fib:18,bfs:grid:5,mergesort:256")?;
    let builds: Vec<_> = specs
        .iter()
        .map(|s| s.instantiate())
        .collect::<anyhow::Result<_>>()?;

    let mut sched = FusedScheduler::new(SchedConfig::default());
    sched.on_complete(|fj| {
        println!(
            "  tenant {} finished after riding {} shared epochs",
            fj.label, fj.stats.steps_ridden
        );
    });
    for b in &builds {
        sched.admit_build(b);
    }
    sched.run_to_completion()?;

    let model = GpuModel::default();
    println!("\nper-tenant results (verified against app oracles):");
    for fj in sched.finished() {
        let m = fj.engine.machine().expect("interp engine");
        let kind = fj.kind.as_ref().unwrap();
        kind.verify(m).map_err(anyhow::Error::msg)?;
        println!(
            "  {:<18} {:<28} V_inf saved ~{:.0} us",
            fj.label,
            kind.describe(m),
            fj.stats.vinf_saved_us(&model)
        );
    }
    let s = sched.stats();
    let solo_launches: u64 =
        sched.finished().iter().map(|f| f.stats.solo_launches).sum();
    println!(
        "\n{} shared epochs, {} fused launches vs {} solo launches \
         ({} saved): one launch pays V_inf for every tenant.",
        s.steps,
        s.launches,
        solo_launches,
        solo_launches - s.launches
    );
    Ok(())
}
