//! Multi-tenant epoch fusion behind the `Session` facade: serve
//! several concurrent jobs from one shared epoch loop, with one of
//! them arriving online, mid-run.
//!
//!     cargo run --release --example multi_tenant
//!
//! Two heterogeneous tenants (fib, BFS) are submitted up front; a
//! mergesort arrives at epoch 6 — the session instantiates it at
//! submit time and it joins the fused task vector at the next epoch
//! boundary. Each shared epoch packs the live task fronts into one
//! task vector at per-job base offsets, so a single launch and a
//! single epoch synchronization pay V∞ for everyone — then each result
//! is cross-checked against its app oracle. No artifacts needed: this
//! drives the pure-Rust fused engine.

use trees::session::{Arrival, Session};
use trees::simt::GpuModel;

fn main() -> anyhow::Result<()> {
    let arrivals =
        Arrival::parse_feed("fib:18,bfs:grid:5,mergesort:256@6")?;

    let mut session = Session::builder().build()?;
    session.run_feed(
        &arrivals,
        |id, a| println!("  @{:<3} admitted {id} {}", a.at_step, a.label()),
        |r| {
            println!(
                "  @{:<3} tenant {} finished after riding {} shared epochs",
                r.at_step, r.job.label, r.job.stats.steps_ridden
            )
        },
    )?;

    let model = GpuModel::default();
    println!("\nper-tenant results (verified against app oracles):");
    for r in session.results() {
        assert_eq!(r.verified(), Some(true), "{}", r.job.label);
        println!(
            "  {:<18} {:<28} V_inf saved ~{:.0} us",
            r.job.label,
            r.summary(),
            r.job.stats.vinf_saved_us(&model)
        );
    }
    let s = session.stats();
    let solo_launches: u64 =
        session.results().iter().map(|r| r.job.stats.solo_launches).sum();
    println!(
        "\n{} shared epochs, {} fused launches vs {} solo launches \
         ({} saved): one launch pays V_inf for every tenant.",
        s.steps,
        s.launches,
        solo_launches,
        solo_launches - s.launches
    );
    Ok(())
}
